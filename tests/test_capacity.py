"""Crash-consistent capacity tier (ISSUE 8 / DESIGN.md §2.11).

Covers: the page-aligned format-3 save layout (roundtrip incl. 0-d and
empty arrays, mmap reads, truncation/bit-flip rejection, atomic
publish), the CRC-framed write-ahead journal (replay order, torn-tail
stop), CapacityTier durability (reopen = manifest + replay + CRC sweep,
injected checkpoint crashes and torn journal frames, disk budget
demotion), a subprocess SIGKILL harness (tier-level and through
``MemoSession.load``), write-through admission / demotion / promotion
on ``MemoStore`` (bit-identical round-trips for all three codecs via a
hypothesis property test, corrupt-row quarantine through the retire
path, the stall watchdog), the DISK_DEGRADED health rung + bounded
``health_log`` ring, and fail-fast unknown chaos-preset names.
"""
import os
import shutil
import signal
import subprocess
import sys
import tempfile
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.core.capacity import (CapacityTier, Journal, is_format3,
                                 read_format3, write_format3)
from repro.core.codec import get_codec
from repro.core.faults import (CHAOS_PRESETS, FAULT_POINTS, FaultInjector,
                               MemoStoreError)
from repro.core.runtime import Health
from repro.core.store import MemoStore
from repro.memo import MemoSession, MemoSpec

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO_ROOT, "src")

SEQ = 32
APM = (2, 4, 4)
EMB = 8


def _entries(rng, n):
    apms = rng.random((n, *APM)).astype(np.float16)
    embs = rng.normal(0, 0.01, (n, EMB)).astype(np.float32)
    embs[:, 0] += 10.0 * np.arange(1, n + 1)   # well separated
    return apms, embs


def _tier(root, **kw):
    kw.setdefault("codec", get_codec("f16", APM))
    kw.setdefault("embed_dim", EMB)
    return CapacityTier(str(root), **kw)


def _tier_rows(rng, codec, n):
    apms = rng.random((n, *APM)).astype(np.float16)
    parts = codec.encode(apms)
    embs = rng.normal(0, 1, (n, EMB)).astype(np.float32)
    return parts, embs, np.full(n, SEQ, np.int32)


# ------------------------------------------------------------- format 3

def test_format3_roundtrip_plain_and_mmap(tmp_path):
    path = str(tmp_path / "f.m3")
    arrays = {
        "scalar": np.asarray(7, np.int64),          # 0-d must stay 0-d
        "empty": np.zeros((0, 3), np.float32),
        "flags": np.asarray([True, False, True]),
        "apm": np.arange(24, dtype=np.float16).reshape(2, 3, 4),
        "big": np.arange(5000, dtype=np.int32),     # crosses a page
    }
    meta = {"format": 3, "nested": {"a": [1, 2]}, "name": "x"}
    assert write_format3(path, meta, arrays)
    assert is_format3(path)
    for mmap in (False, True):
        m, a = read_format3(path, mmap=mmap, verify=not mmap)
        assert m == meta
        assert set(a) == set(arrays)
        for k in arrays:
            assert a[k].shape == arrays[k].shape
            assert a[k].dtype == arrays[k].dtype
            np.testing.assert_array_equal(np.asarray(a[k]), arrays[k])
        if mmap:
            assert isinstance(a["big"], np.memmap)
            # every segment is page-aligned (the mmap contract)
            m2, _ = read_format3(path, verify=False)
            assert m2 == meta


def test_format3_rejects_truncation_and_bitflip(tmp_path):
    path = str(tmp_path / "f.m3")
    write_format3(path, {"k": 1}, {"x": np.arange(4096, dtype=np.int64)})
    torn = str(tmp_path / "torn.m3")
    shutil.copy(path, torn)
    with open(torn, "rb+") as f:
        f.truncate(os.path.getsize(torn) // 2)
    with pytest.raises(MemoStoreError, match="truncated or corrupt"):
        read_format3(torn)
    flip = str(tmp_path / "flip.m3")
    shutil.copy(path, flip)
    with open(flip, "rb+") as f:                  # flip a segment byte
        f.seek(os.path.getsize(flip) - 8)
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(MemoStoreError, match="checksum mismatch"):
        read_format3(flip)
    assert not is_format3(str(tmp_path / "missing.m3"))


def test_format3_atomic_write_never_clobbers(tmp_path):
    """An injected crash between the temp write and the publish leaves
    the existing good file byte-identical (satellite: atomic save)."""
    path = str(tmp_path / "f.m3")
    write_format3(path, {"v": 1}, {"x": np.arange(8)})
    before = open(path, "rb").read()
    inj = FaultInjector()
    inj.arm("session.save_truncate", at=1, count=1)
    ok = write_format3(path, {"v": 2}, {"x": np.arange(9)},
                       faults=inj, fault_point="session.save_truncate")
    assert ok is False
    assert open(path, "rb").read() == before
    meta, _ = read_format3(path)
    assert meta == {"v": 1}
    # the raising flavor (CapacityTier.checkpoint's contract)
    inj2 = FaultInjector()
    inj2.arm("session.save_truncate", at=1, count=1)
    with pytest.raises(OSError, match="injected crash"):
        write_format3(path, {"v": 3}, {"x": np.arange(9)}, faults=inj2,
                      fault_point="session.save_truncate",
                      fault_raises=True)
    assert open(path, "rb").read() == before


# -------------------------------------------------------------- journal

def test_journal_append_replay_roundtrip(tmp_path):
    j = Journal(str(tmp_path / "j.wal"))
    a = {"slots": np.asarray([0, 1]), "embs": np.eye(2, dtype=np.float32)}
    j.append("append", a)
    j.append("retire", {"slots": np.asarray([1])})
    recs, torn = j.replay()
    assert not torn and [k for k, _ in recs] == ["append", "retire"]
    np.testing.assert_array_equal(recs[0][1]["embs"], a["embs"])
    j.truncate()
    assert j.replay() == ([], False) and j.nbytes == 0
    j.close()


def test_journal_torn_tail_stops_cleanly(tmp_path):
    path = str(tmp_path / "j.wal")
    j = Journal(path)
    j.append("append", {"slots": np.asarray([0])})
    j.append("append", {"slots": np.asarray([1])})
    with open(path, "rb+") as f:                  # crash mid-frame
        f.truncate(os.path.getsize(path) - 3)
    recs, torn = j.replay()
    assert torn and len(recs) == 1
    np.testing.assert_array_equal(recs[0][1]["slots"], [0])
    # the injected flavor: a torn frame hits the disk, the append fails
    inj = FaultInjector()
    inj.arm("capacity.journal_torn", at=2, count=1, frac=0.4)
    j2 = Journal(str(tmp_path / "j2.wal"), faults=inj)
    j2.append("append", {"slots": np.asarray([0])})
    with pytest.raises(OSError, match="torn journal frame"):
        j2.append("append", {"slots": np.asarray([1])})
    recs2, torn2 = j2.replay()
    assert torn2 and len(recs2) == 1
    j.close(), j2.close()


# -------------------------------------------------------- capacity tier

def test_tier_append_retire_verify(tmp_path):
    rng = np.random.default_rng(0)
    t = _tier(tmp_path / "t", capacity=4)
    parts, embs, lens = _tier_rows(rng, t.codec, 6)
    slots = t.append(parts, embs, lens)
    assert t.live_count == 6 and t.verify().size == 0
    got_parts, got_embs, got_lens, _ = t.rows_at(slots)
    for p, g in zip(parts, got_parts):
        assert np.asarray(g).tobytes() == np.asarray(p).tobytes()
    np.testing.assert_array_equal(np.asarray(got_embs), embs)
    retired = []
    t.on_retire = lambda s: retired.extend(int(x) for x in s)
    t.retire(slots[:2])
    assert t.live_count == 4 and retired == [int(s) for s in slots[:2]]
    d2, hits = t.search(embs[2:3], 1)
    assert int(hits[0, 0]) == int(slots[2]) and d2[0, 0] < 1e-6
    t.close()


def test_tier_budget_retires_coldest_first(tmp_path):
    rng = np.random.default_rng(1)
    codec = get_codec("f16", APM)
    t = _tier(tmp_path / "t", codec=codec, capacity=4,
              budget_bytes=4 * (codec.entry_nbytes + EMB * 4))
    parts, embs, lens = _tier_rows(rng, codec, 4)
    first = t.append(parts, embs, lens)
    t.note_reuse(first[:2])                       # rows 0,1 are hot
    parts2, embs2, lens2 = _tier_rows(rng, codec, 2)
    fresh = t.append(parts2, embs2, lens2)
    assert t.live_count == 4
    live = set(int(s) for s in t.live_slots)
    assert set(int(s) for s in first[:2]) <= live       # hot survived
    assert set(int(s) for s in fresh) <= live           # fresh excluded
    assert t.n_retired == 2
    t.close()


def test_tier_reopen_replays_journal(tmp_path):
    rng = np.random.default_rng(2)
    t = _tier(tmp_path / "t", capacity=4)
    t.append(*_tier_rows(rng, t.codec, 3))
    t.append(*_tier_rows(rng, t.codec, 2))
    t.retire(t.live_slots[:1])
    # no checkpoint, no close: the reopen below is the crash path
    t2 = _tier(tmp_path / "t")
    assert t2.recovery == {"n_replayed": 3, "torn_tail": False,
                           "n_quarantined": 0, "live_after": 4}
    assert t2.live_count == 4 and t2.verify().size == 0
    assert t2.journal.nbytes == 0                 # recovery checkpointed
    t2.close()


def test_tier_torn_journal_tail_loses_only_the_tail(tmp_path):
    rng = np.random.default_rng(3)
    t = _tier(tmp_path / "t", capacity=4)
    t.append(*_tier_rows(rng, t.codec, 2))
    t.append(*_tier_rows(rng, t.codec, 2))
    with open(os.path.join(str(tmp_path / "t"), CapacityTier.JOURNAL),
              "rb+") as f:
        f.truncate(os.path.getsize(f.name) - 5)   # tear the last frame
    t2 = _tier(tmp_path / "t")
    assert t2.recovery["torn_tail"] and t2.recovery["n_replayed"] == 1
    assert t2.live_count == 2 and t2.verify().size == 0
    t2.close()


def test_tier_recovery_quarantines_bitflipped_row(tmp_path):
    rng = np.random.default_rng(4)
    t = _tier(tmp_path / "t", capacity=4)
    slots = t.append(*_tier_rows(rng, t.codec, 3))
    t.checkpoint()
    t.close()
    part0 = t.codec.parts[0]
    with open(os.path.join(str(tmp_path / "t"),
                           f"part_{part0.name}.dat"), "rb+") as f:
        f.seek(int(slots[1]) * part0.entry_nbytes)
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0xFF]))
    t2 = _tier(tmp_path / "t")
    assert t2.recovery["n_quarantined"] == 1
    assert t2.recovery["live_after"] == 2
    assert not t2._live[int(slots[1])]
    assert t2.verify().size == 0
    t2.close()


def test_tier_checkpoint_crash_keeps_old_manifest(tmp_path):
    rng = np.random.default_rng(5)
    inj = FaultInjector()
    t = _tier(tmp_path / "t", capacity=4, faults=inj)
    t.append(*_tier_rows(rng, t.codec, 3))
    inj.arm("capacity.checkpoint_crash", at=1, count=1)
    with pytest.raises(OSError, match="injected crash"):
        t.checkpoint()
    # the old (empty) manifest + intact journal still recover everything
    t2 = _tier(tmp_path / "t")
    assert t2.recovery["n_replayed"] == 1 and t2.live_count == 3
    assert t2.verify().size == 0
    t2.close()


# --------------------------------------------- SIGKILL subprocess harness

_CHILD = textwrap.dedent("""\
    import json, sys
    import numpy as np
    from repro.core.capacity import CapacityTier
    from repro.core.codec import get_codec

    root, shape, emb, codec_name = (sys.argv[1],
                                    tuple(json.loads(sys.argv[2])),
                                    int(sys.argv[3]), sys.argv[4])
    codec = get_codec(codec_name, shape)
    t = CapacityTier(root, codec=codec, embed_dim=emb, capacity=8)
    rng = np.random.default_rng(int(sys.argv[5]))
    print("READY", flush=True)
    i = 0
    while True:
        apms = rng.random((2, *shape)).astype(np.float16)
        t.append(codec.encode(apms),
                 rng.normal(size=(2, emb)).astype(np.float32),
                 np.full(2, shape[-1], np.int32))
        print("A", flush=True)      # acked: the rows are journal-durable
        if i % 2 == 0:
            t.checkpoint()
        i += 1
""")


def _kill_round(root, shape, emb, codec_name, delay, seed):
    """Run the append/checkpoint child against ``root`` and SIGKILL it
    ``delay`` seconds after READY; returns the number of acked appends
    (each durably journaled before the ack)."""
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.Popen(
        [sys.executable, "-c", _CHILD, str(root),
         str(list(shape)).replace("(", "[").replace(")", "]"),
         str(emb), codec_name, str(seed)],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, env=env)
    try:
        assert proc.stdout.readline().strip() == b"READY", \
            proc.stderr.read().decode()
        time.sleep(delay)
        proc.send_signal(signal.SIGKILL)
        out, _ = proc.communicate(timeout=30)
    finally:
        if proc.poll() is None:
            proc.kill()
    return sum(1 for ln in out.splitlines() if ln.strip() == b"A")


def test_sigkill_at_random_points_recovers_clean(tmp_path):
    """SIGKILL the tier child at randomized instants across several
    crash→recover cycles: every reopen must verify clean and keep at
    least every acked (journal-durable) row (tentpole acceptance)."""
    root = str(tmp_path / "t")
    rng = np.random.default_rng(0)
    acked_rows = 0
    for trial in range(3):
        acked_rows += 2 * _kill_round(
            root, APM, EMB, "f16",
            float(rng.uniform(0.05, 0.35)), seed=trial)
        t = _tier(root)                           # recovery on open
        assert t.recovery is not None
        assert t.verify().size == 0
        assert t.live_count >= acked_rows
        acked_rows = t.live_count                 # next round builds on it
        t.close()
    assert acked_rows > 0


# -------------------------------------------- single-writer lock (ISSUE 9)

def test_lockfile_refuses_live_second_writer(tmp_path):
    """Two processes must never journal one dir: a subprocess opening a
    dir we hold the lock on gets an actionable MemoStoreError naming the
    owning pid and the lockfile."""
    root = str(tmp_path / "t")
    t = _tier(root)
    code = textwrap.dedent(f"""\
        from repro.core.capacity import CapacityTier
        from repro.core.codec import get_codec
        from repro.core.faults import MemoStoreError
        try:
            CapacityTier({root!r}, codec=get_codec("f16", {APM!r}),
                         embed_dim={EMB})
        except MemoStoreError as e:
            print("CONFLICT", e)
        else:
            print("NO-CONFLICT")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=dict(os.environ, PYTHONPATH=SRC))
    assert "CONFLICT" in r.stdout, r.stdout + r.stderr
    assert str(os.getpid()) in r.stdout          # names the owner
    assert "LOCK" in r.stdout                    # names the lockfile
    t.close()
    assert not os.path.exists(os.path.join(root, "LOCK"))


def test_lockfile_stale_and_same_pid_reclaimed(tmp_path):
    """A lock naming a dead pid (SIGKILL'd writer) or our own pid (a
    same-process reopen) is reclaimed, not refused; garbage content
    counts as stale."""
    root = str(tmp_path / "t")
    _tier(root).close()
    for content in ["999999999\n", "not-a-pid", ""]:
        with open(os.path.join(root, "LOCK"), "w") as f:
            f.write(content)
        t = _tier(root)
        with open(os.path.join(root, "LOCK")) as f:
            assert int(f.read()) == os.getpid()
        t.close()
    t = _tier(root)                  # same-pid double-open: takeover
    t2 = _tier(root)
    t2.close()


# ------------------------------------------------ re-compaction (ISSUE 9)

def test_compact_returns_bytes_and_preserves_rows(tmp_path):
    rng = np.random.default_rng(0)
    t = _tier(tmp_path / "t")
    parts, embs, lens = _tier_rows(rng, t.codec, 20)
    slots = t.append(parts, embs, lens)
    t.retire(slots[5:15])
    assert t.retired_fraction == pytest.approx(0.5)
    keep = np.asarray([0, 1, 2, 3, 4, 15, 16, 17, 18, 19])
    old_bytes = sum(os.path.getsize(p) for p in t._arena_paths())
    rep = t.compact()
    assert rep["epoch"] == 1 and rep["live"] == 10
    assert rep["slots_reclaimed"] == 10 and rep["bytes_returned"] > 0
    assert sum(os.path.getsize(p) for p in t._arena_paths()) < old_bytes
    # dense renumbering: old live_slots[i] -> i, bytes intact
    assert t.live_count == 10 and t._n == 10 and t.verify().size == 0
    got, gembs, glens, _ = t.rows_at(np.arange(10))
    for g, p in zip(got, parts):
        assert g.tobytes() == np.ascontiguousarray(p[keep]).tobytes()
    assert np.array_equal(gembs, embs[keep])
    # epoch-0 files gone, reopen sees the new epoch
    assert not os.path.exists(t._part_path(t.codec.parts[0], 0))
    t.close()
    t = _tier(tmp_path / "t")
    assert t.epoch == 1 and t.live_count == 10 and t.verify().size == 0
    t.close()


def test_compact_crash_keeps_old_epoch_and_gcs_strays(tmp_path):
    """``capacity.compact_crash`` fires after the new epoch is staged,
    before the manifest publish: the tier must roll back in-process, and
    a reopen must serve the OLD epoch and GC the stray files."""
    rng = np.random.default_rng(1)
    inj = FaultInjector()
    t = _tier(tmp_path / "t", faults=inj)
    parts, embs, lens = _tier_rows(rng, t.codec, 12)
    slots = t.append(parts, embs, lens)
    t.retire(slots[:6])
    inj.arm("capacity.compact_crash", count=1)
    with pytest.raises(OSError):
        t.compact()
    assert t.epoch == 0 and t.live_count == 6      # rolled back
    strays = [f for f in os.listdir(str(tmp_path / "t")) if ".e1." in f]
    assert strays                                  # staged files remain
    t.close()
    t = _tier(tmp_path / "t")
    assert t.epoch == 0 and t.live_count == 6 and t.verify().size == 0
    assert not [f for f in os.listdir(str(tmp_path / "t")) if ".e1." in f]
    rep = t.compact()                              # disarmed: succeeds
    assert rep["epoch"] == 1 and t.live_count == 6
    t.close()


def test_store_compact_capacity_remaps_disk_slots(tmp_path):
    """Store-level trigger: compaction renumbers disk slots, so the
    host↔disk write-through maps must be rewritten — demotion after a
    compaction must still be free (no re-append)."""
    rng = np.random.default_rng(2)
    s = MemoStore(APM, EMB, capacity=16, capacity_dir=str(tmp_path / "t"))
    apms, embs = _entries(rng, 8)
    s.admit(apms, embs)
    s.evict(4)                                     # demote 4 to disk
    s.capacity.retire(np.asarray(
        [s._host_to_disk[h] for h in list(s._host_to_disk)[:2]]))
    assert s.compact_capacity(min_retired=0.9) is None   # below threshold
    rep = s.compact_capacity(min_retired=0.1)
    assert rep is not None and rep["live"] == 6
    # maps now name the dense slots — and stay consistent both ways
    assert all(0 <= d < 6 for d in s._host_to_disk.values())
    for h, d in s._host_to_disk.items():
        assert s._disk_to_host[d] == h
    # demoting everything re-appends ONLY the two rows whose disk
    # copies were retired — the six remapped mirrors are still free
    before = s.capacity.n_appended
    s.evict(8)
    assert s.capacity.n_appended == before + 2
    assert s.capacity.verify().size == 0


def test_compact_ratio_spec_plumbing_and_idempotence(tmp_path):
    """``CapacitySpec.compact_ratio`` validates and round-trips through
    the flat view (the ``MemoServer._after_apply`` trigger reads it);
    compaction below the threshold — or right after one — is a no-op."""
    spec = MemoSpec.flat(capacity_compact_ratio=0.5)
    assert spec.capacity.compact_ratio == 0.5
    assert spec.capacity_compact_ratio == 0.5      # flat property
    with pytest.raises(ValueError):
        MemoSpec.flat(capacity_compact_ratio=1.5)
    s = MemoStore(APM, EMB, capacity=16, capacity_dir=str(tmp_path / "t"))
    rng = np.random.default_rng(3)
    apms, embs = _entries(rng, 8)
    s.admit(apms, embs)
    s.capacity.retire(s.capacity.live_slots[:4])
    assert s.capacity.retired_fraction >= 0.5
    rep = s.compact_capacity(0.5)
    assert rep is not None and s.capacity.n_compactions == 1
    assert s.compact_capacity(0.5) is None         # nothing left to do


_COMPACT_CHILD = textwrap.dedent("""\
    import json, sys
    import numpy as np
    from repro.core.capacity import CapacityTier
    from repro.core.codec import get_codec

    root, shape, emb = (sys.argv[1], tuple(json.loads(sys.argv[2])),
                        int(sys.argv[3]))
    codec = get_codec("f16", shape)
    t = CapacityTier(root, codec=codec, embed_dim=emb, capacity=8)
    rng = np.random.default_rng(int(sys.argv[4]))
    print("READY", flush=True)
    while True:
        apms = rng.random((4, *shape)).astype(np.float16)
        slots = t.append(codec.encode(apms),
                         rng.normal(size=(4, emb)).astype(np.float32),
                         np.full(4, shape[-1], np.int32))
        t.retire(slots[:2])
        print("A", flush=True)   # acked: +2 live rows journal-durable
        t.compact()              # SIGKILL may land anywhere in here
""")


def test_sigkill_mid_compaction_reopens_clean(tmp_path):
    """Kill-harness round for compaction: a child that compacts after
    every append/retire cycle is SIGKILL'd at random instants — every
    reopen must verify clean, keep every acked live row, and leave
    exactly one epoch's arena files on disk."""
    root = str(tmp_path / "t")
    rng = np.random.default_rng(0)
    env = dict(os.environ, PYTHONPATH=SRC)
    acked_live = 0
    for trial in range(3):
        proc = subprocess.Popen(
            [sys.executable, "-c", _COMPACT_CHILD, root,
             str(list(APM)), str(EMB), str(trial)],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, env=env)
        try:
            assert proc.stdout.readline().strip() == b"READY", \
                proc.stderr.read().decode()
            time.sleep(float(rng.uniform(0.05, 0.35)))
            proc.send_signal(signal.SIGKILL)
            out, _ = proc.communicate(timeout=30)
        finally:
            if proc.poll() is None:
                proc.kill()
        acked_live += 2 * sum(1 for ln in out.splitlines()
                              if ln.strip() == b"A")
        t = _tier(root)                           # recovery on open
        assert t.recovery is not None
        assert t.verify().size == 0
        assert t.live_count >= acked_live
        # exactly one epoch's files survive the GC
        suffixes = {f.split("part_apm")[-1]
                    for f in os.listdir(root) if f.startswith("part_apm")}
        assert len(suffixes) == 1
        acked_live = t.live_count
        t.close()
    assert acked_live > 0


# --------------------------------------- store: write-through / promotion

def test_write_through_then_demotion_is_free(tmp_path):
    rng = np.random.default_rng(0)
    s = MemoStore(APM, EMB, capacity=8, capacity_dir=str(tmp_path / "t"))
    apms, embs = _entries(rng, 6)
    slots = s.admit(apms, embs)
    assert s.capacity_ok and s.capacity.live_count == 6
    assert len(s._host_to_disk) == 6              # mirrored at admission
    before = s.capacity.n_appended
    demoted = s.evict(2)
    assert len(demoted) == 2 and s.stats.n_demoted == 2
    assert s.capacity.live_count == 6             # disk copies survive
    assert s.capacity.n_appended == before        # no re-append needed
    assert s.live_count == 4
    assert slots is not None


@settings(max_examples=6, deadline=None)
@given(codec_name=st.sampled_from(["f16", "int8", "lowrank"]),
       n=st.integers(2, 5), seed=st.integers(0, 10_000))
def test_demote_promote_roundtrip_bit_identical(tmp_path, codec_name, n,
                                                seed):
    """Property (satellite): demote → promote round-trips every codec
    part bit-identically, for all three codecs."""
    d = tempfile.mkdtemp(dir=str(tmp_path))
    rng = np.random.default_rng(seed)
    s = MemoStore(APM, EMB, capacity=16, codec=codec_name,
                  capacity_dir=os.path.join(d, "t"))
    apms, embs = _entries(rng, n)
    slots = s.admit(apms, embs)
    before = [np.asarray(p).copy() for p in s.db.parts_at(slots)]
    assert s.capacity_ok
    s.evict(n)
    assert s.live_count == 0 and s.stats.n_demoted == n
    satisfied = s.promote_for(embs, threshold=0.5)
    assert satisfied.all() and s.stats.n_promoted == n
    _, idx = s.lookup(embs, 1)
    after = s.db.parts_at(idx[:, 0])
    for b, a in zip(before, after):
        assert np.asarray(a).tobytes() == b.tobytes()


def test_promote_quarantines_corrupt_disk_rows(tmp_path):
    rng = np.random.default_rng(7)
    s = MemoStore(APM, EMB, capacity=8, capacity_dir=str(tmp_path / "t"))
    apms, embs = _entries(rng, 3)
    s.admit(apms, embs)
    s.evict(3)
    bad_disk = int(s.capacity.live_slots[1])
    row = np.asarray(s.capacity._parts[0][bad_disk]).copy()
    row.view(np.uint8).reshape(-1)[0] ^= 0xFF     # flip, checksum stale
    s.capacity._parts[0][bad_disk] = row
    satisfied = s.promote_for(embs, threshold=0.5)
    assert s.stats.n_disk_quarantined == 1
    assert int(satisfied.sum()) == 2              # the corrupt one missed
    assert s.capacity.live_count == 2             # retired on disk too
    assert s.capacity.verify().size == 0


def test_promotion_respects_length_gate(tmp_path):
    rng = np.random.default_rng(8)
    s = MemoStore(APM, EMB, capacity=8, capacity_dir=str(tmp_path / "t"))
    apms, embs = _entries(rng, 2)
    s.admit(apms, embs, lengths=np.asarray([SEQ, SEQ // 2]))
    s.evict(2)
    sat = s.promote_for(embs, lengths=np.asarray([SEQ, SEQ]),
                        threshold=0.5)
    assert bool(sat[0]) and not bool(sat[1])      # wrong length: no hit


def test_adopt_capacity_hottest_first_budget_capped(tmp_path):
    rng = np.random.default_rng(9)
    d = str(tmp_path / "t")
    a = MemoStore(APM, EMB, capacity=16, capacity_dir=d)
    apms, embs = _entries(rng, 8)
    a.admit(apms, embs)
    hot_disk = a.capacity.live_slots[:3]
    a.capacity.note_reuse(hot_disk)
    a.checkpoint()
    b = MemoStore(APM, EMB, capacity=16, capacity_dir=d,
                  budget_bytes=3 * a.entry_nbytes)
    assert b.capacity_ok and b.live_count == 0
    assert b.capacity.live_count == 8             # recovered, not wiped
    n = b.adopt_capacity()
    assert n == 3                                 # host budget caps it
    assert set(b._host_to_disk.values()) == set(int(s) for s in hot_disk)
    _, idx = b.lookup(b._embs_host[sorted(b._host_to_disk)], 1)
    assert (np.asarray(idx[:, 0]) >= 0).all()


def test_stall_watchdog_detaches_tier(tmp_path):
    inj = FaultInjector()
    inj.arm("capacity.disk_write_io", at=1, count=1, stall_s=0.2)
    s = MemoStore(APM, EMB, capacity=8, capacity_dir=str(tmp_path / "t"),
                  capacity_stall_s=0.05, faults=inj)
    rng = np.random.default_rng(10)
    apms, embs = _entries(rng, 2)
    slots = s.admit(apms, embs)                   # stalled write-through
    assert slots.size == 2                        # admission survived
    assert not s.capacity_ok
    assert "TimeoutError" in s.capacity_error
    assert s.stats.n_disk_errors == 1


def test_disk_write_error_detaches_then_reattach(tmp_path):
    inj = FaultInjector()
    s = MemoStore(APM, EMB, capacity=8, capacity_dir=str(tmp_path / "t"),
                  faults=inj)
    rng = np.random.default_rng(11)
    apms, embs = _entries(rng, 4)
    inj.arm("capacity.disk_write_io", at=1, count=1)
    s.admit(apms[:2], embs[:2])                   # write-through fails
    assert not s.capacity_ok and "OSError" in s.capacity_error
    s.admit(apms[2:], embs[2:])                   # RAM-only, no raise
    assert s.live_count == 4
    assert s.reattach_capacity()
    assert s.capacity_ok
    # the outage's admissions were re-mirrored on reattach
    assert s.capacity.live_count == 4
    assert len(s._host_to_disk) == 4
    assert s.verify_integrity() == []


# ------------------------------------------------- serving: health + ring

@pytest.fixture(scope="module")
def cap_sess(tmp_path_factory):
    from repro.configs import get_reduced
    from repro.data import TemplateCorpus
    from repro.models import build_model

    tier_dir = str(tmp_path_factory.mktemp("captier") / "tier")
    cfg = get_reduced("bert_base").replace(n_classes=4, n_layers=2,
                                           d_model=128, d_ff=256,
                                           n_heads=4)
    m = build_model(cfg, layer_loop="unroll")
    params = m.init(jax.random.PRNGKey(0))
    corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=SEQ, n_templates=6,
                            slot_fraction=0.2)
    spec = MemoSpec.flat(threshold=0.6, embed_steps=40, mode="bucket",
                         device_slack=8.0, admit=True, budget_mb=64.0,
                         faults={}, capacity_dir=tier_dir,
                         capacity_checkpoint_every=1)
    sess = MemoSession.build(
        m, params, spec,
        batches=[{"tokens": jnp.asarray(corpus.sample(16)[0])}
                 for _ in range(3)],
        key=jax.random.PRNGKey(1))
    assert sess.store.capacity_ok
    assert os.path.exists(os.path.join(tier_dir, "session.m3"))
    return sess, corpus, m, params, tier_dir


def _serve_some(srv, corpus, n=4):
    comps = []
    for _ in range(n):
        toks = corpus.sample(8)[0]
        for r in range(8):
            srv.submit(np.asarray(toks[r], np.int32))
        comps.extend(srv.step(flush=True))
    return comps


def test_disk_fault_walks_ladder_and_recovers(cap_sess):
    """disk_write_io detaches the tier → DISK_DEGRADED; clean applies
    do NOT heal it (no silent un-detach); ``recover()`` reattaches,
    re-checkpoints and returns to HEALTHY (tentpole acceptance)."""
    sess, corpus, _, _, _ = cap_sess
    inj = sess.engine.faults
    inj.disarm(), inj.reset()
    srv = sess.serve(buckets=(SEQ,), max_batch=8, max_delay=1e-4)
    try:
        inj.arm("capacity.disk_write_io", p=1.0)
        comps = _serve_some(srv, corpus, n=3)
        srv.drain_maintenance(timeout=30, raise_errors=False)
        assert len(comps) == 24                   # zero dropped requests
        assert srv.health is Health.DISK_DEGRADED
        assert not sess.store.capacity_ok
        assert srv.n_health_transitions >= 1
        t, h, reason = srv.health_log[-1]
        assert h == "disk_degraded" and "capacity tier detached" in reason
        inj.disarm()
        _serve_some(srv, corpus, n=2)             # clean applies...
        srv.drain_maintenance(timeout=30, raise_errors=False)
        assert srv.health is Health.DISK_DEGRADED  # ...never auto-heal
        report = srv.recover()
        assert report["capacity_ok"] is True
        assert srv.health is Health.HEALTHY
        assert sess.store.capacity_ok
        # checkpoint cadence resumes post-recovery (checkpoint_every=1)
        before = srv.n_checkpoints
        _serve_some(srv, corpus, n=2)
        srv.drain_maintenance(timeout=30, raise_errors=False)
        assert srv.n_checkpoints > before
        assert srv.health is Health.HEALTHY
    finally:
        inj.disarm(), inj.reset()
        srv.close()
    assert sess.store.verify_integrity() == []


def test_health_log_ring_is_bounded(cap_sess):
    sess, _, _, _, _ = cap_sess
    srv = sess.serve(buckets=(SEQ,), max_batch=8, max_delay=1e-4,
                     async_maintenance=False, health_log_cap=4)
    try:
        for i in range(5):                        # 10 transitions
            srv._set_health(Health.DEGRADED, f"flap {i}")
            srv._set_health(Health.HEALTHY, f"heal {i}")
        assert len(srv.health_log) == 4           # ring holds the tail
        assert srv.n_health_transitions == 10     # total stays honest
        assert [e[2] for e in srv.health_log] == \
            ["flap 3", "heal 3", "flap 4", "heal 4"]
    finally:
        srv.close()


def test_session_dir_reopens_after_sigkill(cap_sess, tmp_path):
    """Kill a process mid-append/checkpoint on a copy of the session's
    capacity dir, then reopen through ``MemoSession.load``: integrity
    verifies clean and the recovered store serves hits again (tentpole
    acceptance: reopen + verify_integrity + hit-rate recovery)."""
    sess, corpus, m, params, tier_dir = cap_sess
    sess.store.checkpoint()
    d2 = str(tmp_path / "tier_copy")
    shutil.copytree(tier_dir, d2)
    # the clone inherits the ORIGINAL owner's (live) lockfile — exactly
    # the "delete the lockfile if it is wrong" case the error names
    os.remove(os.path.join(d2, CapacityTier.LOCKFILE))
    shape = sess.store.apm_shape
    rng = np.random.default_rng(1)
    for trial in range(2):
        acked = _kill_round(d2, shape, sess.store.embed_dim,
                            sess.store.codec.name,
                            float(rng.uniform(0.05, 0.3)), seed=trial)
        assert acked >= 0
    sess2 = MemoSession.load(d2, m, params)
    assert sess2.store.capacity_ok
    assert sess2.store.capacity.recovery is not None
    assert sess2.store.verify_integrity() == []
    assert sess2.store.live_count > 0
    # hit-rate recovery: the adopted entries answer their own queries
    live = np.flatnonzero(sess2.store.db.live_mask)[:8]
    _, idx = sess2.store.lookup(sess2.store._embs_host[live], 1)
    np.testing.assert_array_equal(np.asarray(idx[:, 0]), live)
    srv = sess2.serve(buckets=(SEQ,), max_batch=8, max_delay=1e-4)
    try:
        comps = _serve_some(srv, corpus, n=2)
        srv.drain_maintenance(timeout=30, raise_errors=False)
        assert len(comps) == 16
        assert srv.health in (Health.HEALTHY, Health.DISK_DEGRADED)
        assert srv.health is Health.HEALTHY
    finally:
        srv.close()


# ------------------------------------------------ fail-fast chaos presets

def test_capacity_fault_points_and_presets_registered():
    for pt in ("capacity.disk_write_io", "capacity.journal_torn",
               "capacity.checkpoint_crash", "capacity.mmap_bitflip"):
        assert pt in FAULT_POINTS
    for cls in ("disk_write_io", "journal_torn", "checkpoint_crash",
                "mmap_bitflip"):
        assert cls in CHAOS_PRESETS


def test_serve_faults_rejects_unknown_class():
    if REPO_ROOT not in sys.path:
        sys.path.insert(0, REPO_ROOT)
    from benchmarks import serve_faults
    with pytest.raises(ValueError, match="unknown chaos classes") as ei:
        serve_faults.collect(quick=True, classes=("bogus",))
    msg = str(ei.value)
    for cls in sorted(CHAOS_PRESETS):
        assert cls in msg                         # lists every choice


def test_launch_server_rejects_unknown_fault(monkeypatch, capsys):
    from repro.launch import server as launch_server
    monkeypatch.setattr(sys, "argv", ["server", "--fault", "bogus"])
    with pytest.raises(SystemExit) as ei:
        launch_server.main()
    assert ei.value.code == 2
    err = capsys.readouterr().err
    assert "invalid choice" in err and "disk_write_io" in err


# ---------------------------------- read-only opener (ISSUE 10 satellite)

def _rows_digest(tier):
    """Order-stable CRC over every live row's parts + embs + lens —
    computed identically by writer and reader to prove byte parity."""
    import zlib
    parts, embs, lens, _ = tier.rows_at(tier.live_slots)
    crc = 0
    for p in parts:
        crc = zlib.crc32(np.ascontiguousarray(p).tobytes(), crc)
    crc = zlib.crc32(np.ascontiguousarray(embs).tobytes(), crc)
    return zlib.crc32(np.ascontiguousarray(lens).tobytes(), crc)


def test_read_only_open_against_live_writer(tmp_path):
    """Cross-process read sharing (ROADMAP item 4): while THIS process
    holds the writer open (LOCK held, journal live), a subprocess opens
    the same directory with ``read_only=True`` — bypassing the pidfile,
    mapping the arenas ``mode='r'``, and replaying the writer's
    un-checkpointed WAL tail into the overlay. The reader sees every
    row byte-identically (checkpointed AND journal-only), verifies
    clean, searches, and every mutator raises MemoStoreError; the
    writer keeps working afterwards."""
    rng = np.random.default_rng(11)
    root = str(tmp_path / "tier")
    t = _tier(root, capacity=4)
    parts, embs, lens = _tier_rows(rng, t.codec, 6)
    t.append(parts, embs, lens)
    t.checkpoint()
    p2, e2, l2 = _tier_rows(rng, t.codec, 2)
    t.append(p2, e2, l2)          # journal-only: overlay rows for readers
    code = textwrap.dedent(f"""\
        import os, sys, zlib
        import numpy as np
        from repro.core.capacity import CapacityTier
        from repro.core.codec import get_codec
        from repro.core.faults import MemoStoreError

        root = {root!r}
        assert os.path.exists(os.path.join(root, "LOCK"))  # writer alive
        t = CapacityTier.open(root, codec=get_codec("f16", (2, 4, 4)),
                              embed_dim=8, read_only=True)
        assert t.read_only and t.recovery["read_only"]
        assert t.journal is None                 # no WAL handle, ever
        bad = t.verify()
        assert bad.size == 0, bad
        sl = t.live_slots
        parts, embs, lens, _ = t.rows_at(sl)
        crc = 0
        for p in parts:
            crc = zlib.crc32(np.ascontiguousarray(p).tobytes(), crc)
        crc = zlib.crc32(np.ascontiguousarray(embs).tobytes(), crc)
        crc = zlib.crc32(np.ascontiguousarray(lens).tobytes(), crc)
        _, got = t.search(embs, k=1)             # overlay rows searchable
        assert (got[:, 0] == sl).all(), got[:, 0]
        for op in (lambda: t.append(parts, embs, lens),
                   lambda: t.retire([int(sl[0])]),
                   lambda: t.checkpoint(),
                   lambda: t.compact()):
            try:
                op()
            except MemoStoreError as e:
                assert "read_only" in str(e), e
            else:
                sys.exit("mutator did not raise on a read-only tier")
        t.close()
        print("RO-OK", t.live_count, t.recovery["overlay_rows"], crc)
        """)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True,
                         env=dict(os.environ, PYTHONPATH=SRC), timeout=120)
    assert "RO-OK" in out.stdout, out.stderr[-3000:]
    _, live, overlay, crc = out.stdout.split()
    assert int(live) == 8
    assert int(overlay) == 2      # exactly the un-checkpointed appends
    assert int(crc) == _rows_digest(t)           # byte parity with writer
    # the reader changed nothing: the writer's lock, journal and arenas
    # all still work
    p3, e3, l3 = _tier_rows(rng, t.codec, 1)
    t.append(p3, e3, l3)
    t.checkpoint()
    assert t.live_count == 9
    assert t.verify().size == 0
    t.close()


def test_read_only_open_requires_manifest(tmp_path):
    """A directory that was never checkpointed has nothing to map."""
    with pytest.raises(MemoStoreError, match="read-only"):
        CapacityTier.open(str(tmp_path / "nope"),
                          codec=get_codec("f16", APM), embed_dim=EMB,
                          read_only=True)


# ------------------------------------------------------------ spec plumbing

def test_capacity_spec_flat_roundtrip_and_validation(tmp_path):
    spec = MemoSpec.flat(capacity_dir=str(tmp_path / "t"),
                         capacity_budget_mb=8.0,
                         capacity_checkpoint_every=4)
    assert spec.capacity.dir == str(tmp_path / "t")
    assert spec.capacity.checkpoint_every == 4
    spec2 = MemoSpec.from_dict(spec.to_dict())
    assert spec2.capacity == spec.capacity
    with pytest.raises(ValueError):
        MemoSpec.flat(capacity_checkpoint_every=0)
    with pytest.raises(ValueError):
        MemoSpec.flat(capacity_stall_s=-1.0)

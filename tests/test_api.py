"""repro.memo public API v1 (ISSUE 5): composable specs, extension
registries, the MemoConfig deprecation shim, and MemoSession
save/load persistence.

Covers the acceptance points:
* invalid codec/index/eviction keys raise at spec construction (and at
  direct MemoStore construction) with the registered choices listed;
* a registered extension is immediately a valid spec value and is
  actually used by the store;
* the flat ``MemoConfig(**kwargs)`` shim produces the identical
  composed spec and emits exactly one DeprecationWarning per process;
* ``save``/``load`` round-trips a populated store (all three codecs,
  flat and clustered device index) to bit-identical host-tier lookups
  and identical logits on a fixed batch, and a loaded session serves
  under MemoServer with hit rate equal to the pre-save session on the
  same trace.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.memo import (
    AdmissionPolicy, CodecSpec, EmbedSpec, EvictionPolicy, IndexSpec,
    MemoConfig, MemoSession, MemoSpec, RuntimeSpec, register_codec,
    register_eviction, register_index)
from repro.memo import specs as specs_mod

SEQ = 32


# ------------------------------------------------------- spec validation

@pytest.mark.parametrize("ctor, needle", [
    (lambda: CodecSpec(name="zstd"), "int8"),
    (lambda: IndexSpec(host="hnsw"), "exact"),
    (lambda: IndexSpec(device="bsp"), "clustered"),
    (lambda: EvictionPolicy(kind="lru"), "clock"),
])
def test_unknown_registry_keys_raise_listing_choices(ctor, needle):
    with pytest.raises(ValueError) as ei:
        ctor()
    msg = str(ei.value)
    assert "registered" in msg and needle in msg


@pytest.mark.parametrize("ctor", [
    lambda: RuntimeSpec(mode="warp"),
    lambda: RuntimeSpec(store="disk"),
    lambda: RuntimeSpec(device_quanta=0),
    lambda: EmbedSpec(act="relu"),
    lambda: EmbedSpec(dim=0),
    lambda: AdmissionPolicy(every=0),
    lambda: AdmissionPolicy(budget_mb=-1.0),
    lambda: CodecSpec(rank=0),
])
def test_value_validation_at_construction(ctor):
    with pytest.raises(ValueError):
        ctor()


def test_flat_view_reads_and_writes_through():
    s = MemoSpec()
    assert s.threshold == s.runtime.threshold
    s.threshold = 0.5
    s.mode = "bucket"
    s.apm_codec = "f16"
    assert s.runtime.threshold == 0.5
    assert s.runtime.mode == "bucket"
    assert s.codec.name == "f16"
    # invalid writes are rejected ATOMICALLY (value unchanged)
    with pytest.raises(ValueError):
        s.mode = "warp"
    assert s.mode == "bucket"
    with pytest.raises(ValueError):
        s.apm_codec = "zstd"
    assert s.apm_codec == "f16"


def test_unknown_flat_field_raises():
    with pytest.raises(TypeError) as ei:
        MemoSpec.flat(thresold=0.9)      # typo
    assert "thresold" in str(ei.value)


def test_component_type_validated_at_construction():
    """MemoSpec(codec=\"int8\") is the likeliest migration typo (the
    flat name is apm_codec); it must fail AT CONSTRUCTION with a hint,
    not later as 'str' has no attribute 'name'."""
    with pytest.raises(TypeError, match="CodecSpec"):
        MemoSpec(codec="int8")
    with pytest.raises(TypeError, match="RuntimeSpec"):
        MemoConfig(runtime="bucket")
    assert "apm_codec" in str(pytest.raises(
        TypeError, lambda: MemoSpec(codec="int8")).value)


# ------------------------------------------------------------ registries

def test_registered_codec_is_valid_spec_value():
    from repro.core.codec import Int8Codec
    register_codec("int8_alias_test",
                   lambda shape, *, rank=None, dtype=None, **_:
                   Int8Codec(shape))
    spec = CodecSpec(name="int8_alias_test")
    assert spec.name == "int8_alias_test"
    from repro.core.codec import get_codec
    assert get_codec("int8_alias_test", (2, 4, 4)).name == "int8"


def test_registered_eviction_policy_is_used_by_the_store():
    from repro.core.store import MemoStore
    calls = []

    def newest_first(store, n):
        calls.append(n)
        live = np.flatnonzero(store.db.live_mask)
        return [int(s) for s in live[::-1][:n]]

    register_eviction("newest_first_test", newest_first)
    s = MemoStore((2, 4, 4), 8, capacity=4, eviction="newest_first_test")
    rng = np.random.default_rng(0)
    apms = rng.random((5, 2, 4, 4)).astype(np.float16)
    embs = rng.normal(0, 0.01, (5, 8)).astype(np.float32)
    embs[:, 0] += 10.0 * np.arange(1, 6)
    slots = s.admit(apms, embs)
    ev = s.evict(2)
    assert calls == [2]
    assert set(ev) == {int(slots[-1]), int(slots[-2])}   # newest went


def test_registered_host_index_resolves_in_store():
    from repro.core.index import ExactIndex
    from repro.core.store import MemoStore
    register_index("exact_alias_test",
                   lambda dim, **_: ExactIndex(dim), tier="host")
    s = MemoStore((2, 4, 4), 8, capacity=4,
                  index_kind="exact_alias_test")
    assert isinstance(s.index, ExactIndex)


def test_store_rejects_unknown_keys_listing_choices():
    from repro.core.store import MemoStore
    with pytest.raises(ValueError, match="registered"):
        MemoStore((2, 4, 4), 8, index_kind="nope")
    with pytest.raises(ValueError, match="registered"):
        MemoStore((2, 4, 4), 8, eviction="nope")
    with pytest.raises(ValueError, match="registered"):
        MemoStore((2, 4, 4), 8, device_index_kind="nope")


# ------------------------------------------------------ MemoConfig shim

def test_flat_shim_maps_identically_and_warns_exactly_once():
    specs_mod._reset_flat_config_warning()
    kwargs = dict(threshold=0.8, mode="bucket", embed_steps=40,
                  admit=True, budget_mb=64.0, apm_codec="f16",
                  index_kind="ivf", nprobe=8, device_slack=2.0)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cfg = MemoConfig(**kwargs)
        MemoConfig(threshold=0.8)        # second call: no second warning
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 1
    assert "MemoSpec" in str(dep[0].message)
    assert cfg == MemoSpec.flat(**kwargs)
    assert cfg != MemoSpec.flat(threshold=0.8)
    # the shim instance IS a MemoSpec (engines take it unchanged)
    assert isinstance(cfg, MemoSpec)
    assert cfg.admission.enabled is True
    assert cfg.index.host == "ivf"


def test_shim_supports_dataclass_protocols():
    """The old flat MemoConfig was a plain dataclass; the shim must keep
    dataclasses.replace and the inherited classmethods working."""
    import dataclasses
    cfg = MemoSpec.flat(threshold=0.8, mode="bucket")
    shim = MemoConfig(threshold=0.8, mode="bucket")
    r = dataclasses.replace(shim, runtime=RuntimeSpec(threshold=0.5,
                                                      mode="bucket"))
    assert r.threshold == 0.5 and r.mode == "bucket"
    assert MemoConfig.flat(threshold=0.8, mode="bucket") == cfg
    assert MemoConfig.from_dict(cfg.to_dict()) == cfg
    assert shim.copy() == cfg


def test_legacy_import_paths_still_work():
    from repro.core import MemoConfig as core_cfg
    from repro.core.engine import MemoConfig as engine_cfg
    assert engine_cfg is MemoConfig
    assert core_cfg is MemoConfig


def test_engine_default_spec_is_not_shared():
    """Satellite: the old ``memo_cfg=MemoConfig()`` default was ONE
    shared instance; mutating one engine's config leaked into every
    other default-constructed engine."""
    from repro.core.engine import MemoEngine

    class _M:
        def __init__(self):
            from repro.configs import get_reduced
            self.cfg = get_reduced("bert_base").replace(n_layers=2)
    m = _M()
    e1 = MemoEngine(m, params=None)
    e2 = MemoEngine(m, params=None)
    assert e1.mc is not e2.mc
    e1.mc.threshold = 0.123
    assert e2.mc.threshold != 0.123


# --------------------------------------------------- session save/load

@pytest.fixture(scope="module")
def tiny_setup():
    from repro.configs import get_reduced
    from repro.data import TemplateCorpus
    from repro.models import build_model

    cfg = get_reduced("bert_base").replace(n_classes=4, n_layers=2,
                                           d_model=128, d_ff=256,
                                           n_heads=4)
    m = build_model(cfg, layer_loop="unroll")
    params = m.init(jax.random.PRNGKey(0))
    corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=SEQ, n_templates=6,
                            slot_fraction=0.2)
    return m, params, corpus


def _build_session(tiny_setup, codec, device_index="auto",
                   cluster_crossover=4096, host_index="exact"):
    m, params, corpus = tiny_setup
    spec = MemoSpec(
        runtime=RuntimeSpec(threshold=0.6, mode="bucket"),
        embed=EmbedSpec(steps=30),
        codec=CodecSpec(name=codec),
        index=IndexSpec(host=host_index, device=device_index,
                        cluster_crossover=cluster_crossover),
        admission=AdmissionPolicy(enabled=True, budget_mb=64.0))
    batches = [{"tokens": jnp.asarray(corpus.sample(16)[0])}
               for _ in range(3)]
    return MemoSession.build(m, params, spec, batches=batches,
                             key=jax.random.PRNGKey(1))


@pytest.mark.parametrize("codec,device_index,crossover,host", [
    ("f16", "auto", 4096, "exact"),      # flat device index
    ("int8", "auto", 4096, "exact"),
    ("lowrank", "auto", 4096, "exact"),
    ("int8", "clustered", 1, "exact"),   # forced clustered device index
    ("f16", "clustered", 1, "exact"),
    ("int8", "auto", 4096, "ivf"),       # approximate host index: the
    #                                      k-means layout must round-trip
])
def test_save_load_roundtrip_bit_identical(tiny_setup, tmp_path, codec,
                                           device_index, crossover, host):
    m, params, corpus = tiny_setup
    sess = _build_session(tiny_setup, codec, device_index, crossover,
                          host_index=host)
    toks = jnp.asarray(corpus.sample(8)[0])
    sess.infer({"tokens": toks})           # mutate: admissions land

    path = tmp_path / f"memo_{codec}_{device_index}_{host}.npz"
    sess.save(path)
    loaded = MemoSession.load(path, m, params)

    # host-tier lookups are BIT-identical (distances and slots)
    q = sess.store.embeddings_at(
        np.arange(min(8, len(sess.store.db))))
    d1, i1 = sess.store.lookup(q, 1)
    d2, i2 = loaded.store.lookup(q, 1)
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_array_equal(d1, d2)

    # entry lengths and liveness round-tripped
    n = len(sess.store.db)
    np.testing.assert_array_equal(sess.store.entry_lengths(np.arange(n)),
                                  loaded.store.entry_lengths(np.arange(n)))
    assert sess.store.sim_cal == loaded.store.sim_cal
    assert loaded.store.codec.name == sess.store.codec.name

    # both serve the identical saved state: same hits, same logits
    out1, st1 = sess.infer({"tokens": toks})
    out2, st2 = loaded.infer({"tokens": toks})
    assert st1.memo_rate == st2.memo_rate
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_loaded_session_serves_with_equal_hit_rate(tiny_setup, tmp_path):
    """Acceptance: a loaded session serves under MemoServer with hit
    rate equal to the pre-save session on the same trace."""
    m, params, corpus = tiny_setup
    sess = _build_session(tiny_setup, "int8")
    sess.infer({"tokens": jnp.asarray(corpus.sample(8)[0])})
    path = tmp_path / "memo_serve.npz"
    sess.save(path)
    loaded = MemoSession.load(path, m, params)

    def serve_trace(session, seed=11):
        rng = np.random.default_rng(seed)
        with session.serve(buckets=(SEQ,), max_batch=8,
                           async_maintenance=False) as server:
            server.warmup()
            for _ in range(3):
                for _ in range(8):
                    server.submit(corpus.sample(1, rng)[0][0])
                server.step(flush=True)
            return server.stats.memo_rate, server.stats.n_hits

    rate_pre, hits_pre = serve_trace(sess)
    rate_post, hits_post = serve_trace(loaded)
    assert hits_pre > 0                       # the trace actually hits
    assert rate_pre == rate_post
    assert hits_pre == hits_post


def test_load_rejects_unknown_format(tiny_setup, tmp_path):
    import json
    path = tmp_path / "bad.npz"
    with open(path, "wb") as f:
        np.savez(f, meta=json.dumps({"format": 999}))
    m, params, _ = tiny_setup
    with pytest.raises(ValueError, match="format"):
        MemoSession.load(path, m, params)

"""Prefill memoization (ISSUE 10 / DESIGN.md §2.13).

Covers: the ``PrefillCodec`` part layout (KV parts appended AFTER the
base parts, so the fused kernel's positional indexing and every arena
consumer stay valid) and its host/device decode parity per KV mode; the
KV stack/unstack helpers; the flat ``prefill_*`` spec fields (inert by
default); engine-level prefill — self-hit decode parity per codec
against exact prefill inside the kernel-parity bounds, the miss path
matching exact prefill, the causal and length-equality hit gates, and
the prefill-only admission-capture gate; MemoServer prefill serving
(per-request cache slices, plain/prefill mixing, the MEMO_DISABLED
exact fallback); session save/load round-tripping the KV arenas; and
the backbone's own prefill+decode == full-forward parity across MHA,
GQA-grouped, and sliding-window attention (RoPE offsets ride the
position bookkeeping in all three).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.codec import get_codec
from repro.core.engine import MemoEngine
from repro.core.prefill import PrefillCodec, stack_kv, unstack_kv_rows
from repro.core.runtime import Health, MemoServer
from repro.data import TemplateCorpus
from repro.memo import MemoSession, MemoSpec, MemoStats
from repro.models import build_model

SEQ = 16
BATCH = 8

# per-codec |Δlogits| ceilings — the same numbers the serve_prefill
# benchmark hard-gates: prefill carries the APM codec's error, decode
# the KV codec's (lowrank KV runs at full rank: K/V spectra decay far
# slower than softmax rows, so truncation is a quality knob while the
# parity gate covers the SVD/quantized-factor machinery)
BOUNDS = {
    "f16":     {"prefill": 5e-3, "decode": 5e-3},
    "int8":    {"prefill": 2e-2, "decode": 2e-2},
    "lowrank": {"prefill": 1e-1, "decode": 5e-2},
}


@functools.lru_cache(maxsize=3)
def _built(codec: str):
    """Prefill-enabled session over the reduced causal GPT-2, cached per
    codec (module-level: several tests share the int8 build)."""
    cfg = get_reduced("gpt2_small")
    model = build_model(cfg, layer_loop="unroll")
    params = model.init(jax.random.PRNGKey(0))
    corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=SEQ, n_templates=8,
                            slot_fraction=0.25, seed=3)
    lowrank = codec == "lowrank"
    spec = MemoSpec.flat(
        threshold=0.6, mode="bucket", embed_steps=40,
        apm_codec=codec, apm_rank=(3 * SEQ) // 4 if lowrank else None,
        prefill_enabled=True,
        prefill_kv_codec="lowrank" if lowrank else "auto",
        prefill_kv_rank=SEQ if lowrank else None)
    rng = np.random.default_rng(17)
    calib = [jnp.asarray(corpus.sample(BATCH, rng)[0]) for _ in range(2)]
    sess = MemoSession.build(model, params, spec,
                             batches=[{"tokens": t} for t in calib],
                             key=jax.random.PRNGKey(1))
    return sess, model, corpus, calib


@pytest.fixture(scope="module")
def pf_engine():
    sess, model, corpus, calib = _built("int8")
    return sess.engine, model, corpus, calib


# ------------------------------------------------------------ codec layer

KV_DIM = 12


def _kv_plane(rng, b, s=SEQ, d=KV_DIM):
    return rng.normal(0, 1.5, (b, 2, s, d)).astype(np.float32)


@pytest.mark.parametrize("kv_mode", ["f16", "int8", "lowrank"])
def test_prefill_codec_roundtrip(kv_mode):
    rng = np.random.default_rng(0)
    base = get_codec("int8", (2, SEQ, SEQ))
    rank = SEQ if kv_mode == "lowrank" else None
    c = PrefillCodec(base, KV_DIM, kv_codec=kv_mode, kv_rank=rank)
    assert c.parts[: c.n_base_parts] == base.parts   # KV strictly appended
    assert c.name == base.name                       # kernel branches on it
    apms = rng.random((3, 2, SEQ, SEQ)).astype(np.float16)
    kv = _kv_plane(rng, 3)
    parts = c.encode(apms, aux=kv)
    # base contract intact: APM decode ignores the KV suffix and matches
    # the base codec bit-for-bit
    np.testing.assert_array_equal(
        np.asarray(c.decode(parts)),
        np.asarray(base.decode(base.encode(apms))))
    got = np.asarray(c.decode_kv(parts), np.float32)
    scale = float(np.abs(kv).max())
    tol = (1e-3 if kv_mode == "f16" else 0.05) * scale
    assert np.abs(got - kv).max() < tol
    # device decode mirrors host decode op-for-op
    dev = np.asarray(c.decode_kv_rows(tuple(jnp.asarray(p)
                                            for p in parts)))
    np.testing.assert_array_equal(dev, np.asarray(c.decode_kv(parts)))


def test_prefill_codec_zero_fallback_and_shape_guard():
    base = get_codec("f16", (2, SEQ, SEQ))
    c = PrefillCodec(base, KV_DIM)
    apms = np.random.default_rng(1).random((2, 2, SEQ, SEQ)) \
        .astype(np.float16)
    parts = c.encode(apms)                 # aux=None: legacy APM-only
    assert np.abs(np.asarray(c.decode_kv(parts))).max() == 0.0
    with pytest.raises(ValueError, match="kv aux shape"):
        c.encode(apms, aux=np.zeros((2, 2, SEQ, KV_DIM + 1), np.float32))


def test_stack_unstack_kv_inverse():
    rng = np.random.default_rng(2)
    hkv, dh = 3, 4
    k = rng.normal(size=(2, SEQ, hkv, dh)).astype(np.float32)
    v = rng.normal(size=(2, SEQ, hkv, dh)).astype(np.float32)
    kv = stack_kv(k, v)
    assert kv.shape == (2, 2, SEQ, hkv * dh)
    k2, v2 = unstack_kv_rows(jnp.asarray(kv), hkv, dh)
    np.testing.assert_array_equal(np.asarray(k2), k)
    np.testing.assert_array_equal(np.asarray(v2), v)


# ------------------------------------------------------------- spec layer

def test_prefill_spec_flat_fields_and_roundtrip():
    spec = MemoSpec.flat(threshold=0.5)
    assert spec.prefill.enabled is False        # inert by default
    spec = MemoSpec.flat(prefill_enabled=True, prefill_cache_len=64,
                         prefill_kv_codec="int8")
    assert spec.prefill.enabled and spec.prefill.cache_len == 64
    assert spec.prefill_kv_codec == "int8"      # flat attribute view
    back = MemoSpec.from_dict(spec.to_dict())
    assert back.prefill.enabled is True
    assert back.prefill.cache_len == 64
    assert back.prefill.kv_codec == "int8"


# ----------------------------------------------------------- engine layer

def _teacher_forced_decode(eng, model, lm, cm, le, ce, steps):
    """Greedy decode both cache sets on the exact leg's tokens; returns
    (max |Δlogits| across steps, agreement fraction)."""
    dmax, agree, total = 0.0, 0, 0
    for step in range(steps):
        tm = jnp.argmax(lm, -1).reshape(-1)
        te = jnp.argmax(le, -1).reshape(-1)
        agree += int((tm == te).sum())
        total += int(te.shape[0])
        pos = jnp.int32(SEQ + step)
        lm, cm = model.decode_step(eng.params, te[:, None], cm, pos)
        le, ce = model.decode_step(eng.params, te[:, None], ce, pos)
        dmax = max(dmax, float(jnp.max(jnp.abs(lm - le))))
    return dmax, agree / max(1, total)


@pytest.mark.parametrize("codec", ["f16", "int8", "lowrank"])
def test_prefill_selfhit_decode_parity(codec):
    """Replaying an admitted prompt hits every memoized layer, and the
    decode cache materialized from the stored KV entry carries greedy
    decode inside the per-codec kernel-parity bounds (acceptance)."""
    sess, model, corpus, calib = _built(codec)
    eng = sess.engine
    batch = {"tokens": calib[0]}
    le, ce = eng.prefill_exact(batch)
    st = MemoStats()
    lm, cm, st = eng.prefill(batch, stats=st)
    assert st.n_layer_attempts > 0
    assert st.n_hits == st.n_layer_attempts          # pure self-hits
    b = BOUNDS[codec]
    assert float(jnp.max(jnp.abs(lm - le))) <= b["prefill"]
    dmax, agree = _teacher_forced_decode(eng, model, lm, cm, le, ce, 4)
    assert dmax <= b["decode"]
    assert agree >= (1.0 if codec == "f16" else 0.9)


def test_prefill_miss_matches_exact(pf_engine):
    """All-miss prefill (threshold above every sim) runs the exact layer
    bodies: logits match ``prefill_exact`` and decode caches agree."""
    eng, model, corpus, _ = pf_engine
    batch = {"tokens": jnp.asarray(corpus.sample(4)[0])}
    le, ce = eng.prefill_exact(batch)
    st = MemoStats()
    lm, cm, st = eng.prefill(batch, threshold=1e9, stats=st)
    assert st.n_hits == 0
    np.testing.assert_allclose(np.asarray(lm), np.asarray(le),
                               rtol=2e-3, atol=2e-3)
    dmax, agree = _teacher_forced_decode(eng, model, lm, cm, le, ce, 2)
    assert dmax <= 2e-3 and agree == 1.0


def test_prefill_length_gate(pf_engine):
    """Stored entries were captured at SEQ; a shorter prompt may NEVER
    replay them even when the threshold passes everything — the length
    gate is part of the hit predicate, not a heuristic."""
    eng, _, corpus, _ = pf_engine
    toks = np.asarray(corpus.sample(4)[0])
    toks[:, SEQ - 4:] = 0                       # padded to the bucket
    lens = np.full(4, SEQ - 4, np.int32)
    _, _, st = eng.prefill({"tokens": jnp.asarray(toks), "lengths": lens},
                           threshold=-1e9, stats=MemoStats())
    assert st.n_hits == 0
    # contrast: same-length traffic at the same threshold is all-hit
    _, _, st2 = eng.prefill({"tokens": jnp.asarray(corpus.sample(4)[0])},
                            threshold=-1e9, stats=MemoStats())
    assert st2.n_hits == st2.n_layer_attempts > 0


def test_prefill_requires_causal():
    """The mask-kind gate: a bidirectional model can never replay
    causal-prefill entries, so the engine refuses at build time."""
    cfg = get_reduced("bert_base").replace(n_layers=2, d_model=128,
                                           d_ff=256, n_heads=4)
    model = build_model(cfg, layer_loop="unroll")
    params = model.init(jax.random.PRNGKey(0))
    eng = MemoEngine(model, params,
                     MemoSpec.flat(prefill_enabled=True, embed_steps=10))
    corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=SEQ)
    with pytest.raises(ValueError, match="causal"):
        eng.build(jax.random.PRNGKey(1),
                  [{"tokens": jnp.asarray(corpus.sample(4)[0])}])


def test_capture_gates_to_prefill_batches(pf_engine):
    """With prefill memoization on, ONLY prefill batches may capture for
    admission: an APM-only capture would admit zero-KV entries whose
    later hits replay an empty decode cache."""
    eng, _, _, _ = pf_engine
    admit0 = eng.mc.admit
    eng.mc.admit = True
    try:
        assert eng._capture_now(True, prefill=True)
        assert not eng._capture_now(True, prefill=False)
    finally:
        eng.mc.admit = admit0


# ----------------------------------------------------------- server layer

def test_server_prefill_serving(pf_engine):
    """Prefill requests come back with per-request decode caches that
    decode in lockstep with exact-prefill caches; plain requests carry
    none; prefill and plain requests never share a batch."""
    eng, model, corpus, calib = pf_engine
    srv = MemoServer(eng, buckets=(SEQ,), max_batch=4,
                     async_maintenance=False)
    try:
        cal = np.asarray(calib[0])
        rids_pf = [srv.submit(cal[i], prefill=True) for i in range(4)]
        rids_pl = [srv.submit(cal[i]) for i in range(2)]
        comps = []
        while srv.queued:
            comps.extend(srv.step(flush=True))
        by_rid = {c.rid: c for c in comps}
        pf = [by_rid[r] for r in rids_pf]
        assert all(c.caches is not None for c in pf)
        assert all(by_rid[r].caches is None for r in rids_pl)
        # per-request cache slices decode in lockstep with exact prefill
        le, ce = eng.prefill_exact({"tokens": jnp.asarray(cal[:4])})
        np.testing.assert_allclose(
            np.stack([c.logits for c in pf]), np.asarray(le),
            rtol=0, atol=BOUNDS["int8"]["prefill"])
        te = jnp.argmax(le, -1).reshape(-1)
        by_li = eng._split_caches(ce)
        dmax = 0.0
        for i, c in enumerate(pf):
            lg, _ = model.decode_step(eng.params, te[i: i + 1][:, None],
                                      c.caches, jnp.int32(SEQ))
            ce_i = eng._merge_caches(
                {li: jax.tree.map(lambda a, i=i: a[i: i + 1], cc)
                 for li, cc in by_li.items()})
            lge, _ = model.decode_step(eng.params, te[i: i + 1][:, None],
                                       ce_i, jnp.int32(SEQ))
            dmax = max(dmax, float(jnp.max(jnp.abs(lg - lge))))
        assert dmax <= BOUNDS["int8"]["decode"]
    finally:
        srv.close()


def test_server_prefill_requires_enabled_spec(pf_engine):
    eng, _, corpus, calib = pf_engine
    srv = MemoServer(eng, buckets=(SEQ,), max_batch=4,
                     async_maintenance=False)
    try:
        eng.mc.prefill.enabled = False
        with pytest.raises(RuntimeError, match="prefill"):
            srv.submit(np.asarray(calib[0])[0], prefill=True)
    finally:
        eng.mc.prefill.enabled = True
        srv.close()


def test_server_prefill_memo_disabled_falls_back_exact(pf_engine):
    """Graceful degradation: with the memo path disabled, prefill
    requests serve through ``prefill_exact`` — same response shape,
    caches included, exact logits."""
    eng, _, _, calib = pf_engine
    srv = MemoServer(eng, buckets=(SEQ,), max_batch=4,
                     async_maintenance=False)
    try:
        srv.health = Health.MEMO_DISABLED
        cal = np.asarray(calib[0])
        rids = [srv.submit(cal[i], prefill=True) for i in range(2)]
        comps = []
        while srv.queued:
            comps.extend(srv.step(flush=True))
        by_rid = {c.rid: c for c in comps}
        le, _ = eng.prefill_exact({"tokens": jnp.asarray(cal[:2])})
        for i, r in enumerate(rids):
            assert by_rid[r].caches is not None
            np.testing.assert_allclose(by_rid[r].logits,
                                       np.asarray(le)[i], rtol=0,
                                       atol=1e-5)
    finally:
        srv.close()


# ---------------------------------------------------------- session layer

def test_session_save_load_roundtrips_kv(tmp_path, pf_engine):
    """Save format 3 persists the KV parts through the codec-driven
    ``state_dict`` untouched: the loaded engine's prefill (hits + stored
    KV) matches the original bit-for-bit."""
    sess, model, _, calib = _built("int8")
    path = str(tmp_path / "sess.m3")
    sess.save(path)
    sess2 = MemoSession.load(path, model, sess.engine.params)
    assert isinstance(sess2.engine.store.codec, PrefillCodec)
    sd, sd2 = sess.store.state_dict(), sess2.store.state_dict()
    assert set(sd) == set(sd2)
    for k in sd:
        assert np.asarray(sd[k]).tobytes() == np.asarray(sd2[k]).tobytes(), k
    batch = {"tokens": calib[0]}
    lm, _, st = sess.engine.prefill(batch, stats=MemoStats())
    lm2, _, st2 = sess2.engine.prefill(batch, stats=MemoStats())
    assert st2.n_hits == st.n_hits > 0
    np.testing.assert_array_equal(np.asarray(lm), np.asarray(lm2))


# ------------------------------------- backbone prefill/decode (satellite)

@pytest.mark.parametrize("arch,over", [
    ("gpt2_small", {}),                        # MHA
    ("qwen3_8b", {}),                          # GQA: 4 heads over 2 KV
    ("gpt2_small", {"sliding_window": 8}),     # local attention window
])
def test_model_prefill_decode_matches_full_forward(arch, over):
    """The decode path the memoized prefill hands its caches to must
    itself be exact: prefill(S0) + K decode steps reproduces the full
    (S0+K)-sequence forward position by position — across GQA grouping,
    sliding windows, and the RoPE rotations the absolute decode
    positions select."""
    cfg = get_reduced(arch).replace(**over) if over else get_reduced(arch)
    model = build_model(cfg, layer_loop="unroll")
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    s0, steps = 8, 4
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, s0 + steps)),
                       jnp.int32)
    full, _, _ = model.forward(params, {"tokens": toks})
    lg, caches = model.prefill(params, {"tokens": toks[:, :s0]},
                               cache_len=s0 + steps)
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(full[:, s0 - 1]),
                               rtol=2e-4, atol=2e-4)
    for k in range(steps):
        lg, caches = model.decode_step(params, toks[:, s0 + k][:, None],
                                       caches, jnp.int32(s0 + k))
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, s0 + k]),
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"decode step {k}")

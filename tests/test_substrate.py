"""Optimizers, schedules, data pipeline, checkpointing, trainer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import TemplateCorpus, lm_batches
from repro.optim import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update, cosine_schedule)
from repro.train.checkpoint import load_checkpoint, save_checkpoint


def _quad_params(key):
    return {"a": jax.random.normal(key, (4, 8)),
            "nest": {"b": jax.random.normal(key, (8,))}}


@pytest.mark.parametrize("opt", ["adamw", "adafactor"])
def test_optimizers_reduce_quadratic(opt):
    key = jax.random.PRNGKey(0)
    params = _quad_params(key)
    target = jax.tree.map(lambda p: p * 0.0 + 1.0, params)
    init, update = ((adamw_init, adamw_update) if opt == "adamw"
                    else (adafactor_init, adafactor_update))
    state = init(params)

    def loss_fn(p):
        return sum(jnp.sum(jnp.square(x - t)) for x, t in
                   zip(jax.tree.leaves(p), jax.tree.leaves(target)))
    l0 = float(loss_fn(params))
    for _ in range(200):
        _, g = jax.value_and_grad(loss_fn)(params)
        params, state = update(params, g, state, lr=3e-2)
    assert float(loss_fn(params)) < l0 * 0.05


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((64, 128)), "b": jnp.zeros((128,))}
    st_ = adafactor_init(params)
    assert st_["s"]["w"]["vr"].shape == (64,)
    assert st_["s"]["w"]["vc"].shape == (128,)
    assert st_["s"]["b"]["v"].shape == (128,)
    # factored state is tiny vs Adam's
    adam = adamw_init(params)
    fac_bytes = sum(x.size * 4 for x in jax.tree.leaves(st_["s"]))
    adam_bytes = sum(x.size * 4 for x in jax.tree.leaves(adam["m"])) * 2
    assert fac_bytes < adam_bytes / 20


def test_grad_clip():
    params = {"w": jnp.ones((4,))}
    state = adamw_init(params)
    g = {"w": jnp.full((4,), 1e6)}
    p2, _ = adamw_update(params, g, state, lr=1.0, grad_clip=1.0)
    assert np.isfinite(np.asarray(p2["w"])).all()


def test_cosine_schedule_shape():
    assert float(cosine_schedule(0, 10, 100, 1.0)) < 0.2
    assert float(cosine_schedule(10, 10, 100, 1.0)) == pytest.approx(1.0,
                                                                     abs=0.1)
    assert float(cosine_schedule(100, 10, 100, 1.0)) < 0.01


# ------------------------------------------------------------------- data

def test_template_corpus_determinism_and_structure():
    c1 = TemplateCorpus(vocab=512, seq_len=32, seed=7)
    c2 = TemplateCorpus(vocab=512, seq_len=32, seed=7)
    t1, l1 = c1.sample(16)
    t2, l2 = c2.sample(16)
    np.testing.assert_array_equal(t1, t2)
    np.testing.assert_array_equal(l1, l2)
    assert t1.shape == (16, 32) and t1.min() >= 0 and t1.max() < 512


@given(frac=st.floats(0.05, 0.9))
@settings(max_examples=10, deadline=None)
def test_template_similarity_knob(frac):
    """Same-template samples share >= (1-frac) of positions."""
    c = TemplateCorpus(vocab=512, seq_len=64, n_templates=1,
                       slot_fraction=frac, seed=3)
    t, _ = c.sample(8)
    agree = (t[0] == t[1]).mean()
    assert agree >= 1.0 - frac - 1e-9


def test_lm_batches_shapes():
    bs = list(lm_batches(vocab=256, seq_len=16, batch_size=4, n_batches=3))
    assert len(bs) == 3
    assert bs[0]["tokens"].shape == (4, 16)


# ------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    params = {"emb": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
              "layers": {"seg0": {"l0": {"w": jnp.ones((4,))}}}}
    opt = adamw_init(params)
    path = os.path.join(tmp_path, "ck.npz")
    save_checkpoint(path, params, opt, step=17, meta={"arch": "t"})
    p2, o2, meta = load_checkpoint(path)
    assert meta["step"] == 17 and meta["arch"] == "t"
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, p2)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), opt, o2)


def test_trainer_reduces_loss():
    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.train import TrainConfig, Trainer

    cfg = get_reduced("gpt2_small").replace(n_layers=2, d_model=128,
                                            d_ff=256, vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=32, seed=5)
    tr = Trainer(model, TrainConfig(steps=30, lr=1e-3, log_every=10))
    logs = []
    params, _, hist = tr.fit(params, lm_batches(
        cfg.vocab, 32, 8, 30, corpus=corpus), on_log=logs.append)
    assert hist[-1][1] < hist[0][1] * 0.9, hist

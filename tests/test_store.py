"""MemoStore lifecycle (ISSUE 2 / DESIGN.md §2.5).

Covers: admission + budget eviction invariants (property-style via the
hypothesis shim), arena slot recycling without aliasing, index↔DB
agreement under interleaved admit/evict/sync, impossibility of hits on
evicted entries, generation-counted no-op sync, delta-sync transfer
accounting, the bounded MemoStats sim reservoir, miss capture on the
device fast path (still zero per-layer host syncs), and online
adaptation (drift → hit-rate collapse → recovery ≥ 2× the frozen store
with logits still matching select).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st_h

import repro.core.engine as engine_mod
import repro.core.store as store_mod
from repro.core.engine import MemoStats, SimReservoir
from repro.core.index import TOMBSTONE
from repro.core.store import MemoStore

APM_SHAPE = (2, 4, 4)
EMB_DIM = 8
# lifecycle invariants must hold under every storage codec (ISSUE 3):
# the compressed payloads ride the same slots/free-list/delta machinery
CODECS = ["f16", "int8", "lowrank"]


def _entries(rng, n):
    """n unique, well-separated entries: embedding i sits at 10·i on the
    first axis so each entry's nearest neighbor is unambiguous."""
    apms = rng.random((n, *APM_SHAPE)).astype(np.float16)
    embs = rng.normal(0, 0.01, (n, EMB_DIM)).astype(np.float32)
    embs[:, 0] += 10.0 * np.arange(1, n + 1)
    return apms, embs


def _mk_store(budget_entries=None, codec="f16"):
    budget = (None if budget_entries is None
              else budget_entries * (MemoStore(
                  APM_SHAPE, EMB_DIM, codec=codec).entry_nbytes))
    return MemoStore(APM_SHAPE, EMB_DIM, capacity=4, budget_bytes=budget,
                     codec=codec)


def _rt(s, apms):
    """What the store must return for ``apms``: the codec round trip
    (bit-exact for f16/int8; lowrank within einsum reassociation)."""
    c = s.db.codec
    return c.decode(c.encode(apms))


def _assert_payload(got, expect):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(expect, np.float32),
                               atol=1e-3, rtol=0)


# ----------------------------------------------------------- admission

@pytest.mark.parametrize("codec", CODECS)
def test_admit_assigns_slots_and_lookup_finds_them(codec):
    rng = np.random.default_rng(0)
    s = _mk_store(codec=codec)
    apms, embs = _entries(rng, 5)
    slots = s.admit(apms, embs)
    assert slots.shape == (5,)
    dist, idx = s.lookup(embs, 1)
    np.testing.assert_array_equal(idx[:, 0], slots)
    # self-distance ~0 up to the matmul-form f32 cancellation (entries
    # are 10.0 apart, so the nearest-id assertion above is the real check)
    assert np.all(dist[:, 0] < 0.1)
    _assert_payload(s.db.get(slots, count_reuse=False), _rt(s, apms))


@pytest.mark.parametrize("codec", CODECS)
def test_budget_eviction_keeps_live_within_budget(codec):
    rng = np.random.default_rng(1)
    s = _mk_store(budget_entries=6, codec=codec)
    for _ in range(5):
        apms, embs = _entries(rng, 3)
        s.admit(apms, embs)
    assert s.live_count <= 6
    assert s.stats.n_evicted >= 15 - 6
    # arena did not balloon past the budget by much (recycling, not append)
    assert len(s.db) <= 6 + 3


@pytest.mark.parametrize("codec", CODECS)
def test_admitting_batch_larger_than_budget_keeps_newest(codec):
    rng = np.random.default_rng(2)
    s = _mk_store(budget_entries=4, codec=codec)
    apms, embs = _entries(rng, 10)
    slots = s.admit(apms, embs)
    assert slots.shape == (4,)
    assert s.live_count == 4
    _assert_payload(s.db.get(slots, count_reuse=False), _rt(s, apms[-4:]))


# ------------------------------------------------------------- eviction

def test_evicted_entry_can_never_hit():
    rng = np.random.default_rng(3)
    s = _mk_store()
    apms, embs = _entries(rng, 6)
    s.admit(apms, embs)
    s.evict(2)  # reuse counts all zero → clock evicts immediately
    evicted = [sl for sl in range(len(s.db)) if not s.db._live[sl]]
    assert len(evicted) == 2
    for ev in evicted:
        # query with the EXACT embedding of the evicted entry: the
        # tombstone must lose to every live entry
        dist, idx = s.lookup(embs[ev][None], 1)
        assert int(idx[0, 0]) != ev


def test_reuse_clock_protects_hot_entries():
    rng = np.random.default_rng(4)
    s = _mk_store()
    apms, embs = _entries(rng, 4)
    slots = s.admit(apms, embs)
    s.note_reuse(np.repeat(slots[1], 5))      # slot 1 is hot
    ev = s.evict(3)
    assert int(slots[1]) not in ev            # survived the sweep
    assert s.db._live[int(slots[1])]


@pytest.mark.parametrize("codec", CODECS)
def test_slot_recycling_never_aliases_live_entries(codec):
    rng = np.random.default_rng(5)
    s = _mk_store(codec=codec)
    apms, embs = _entries(rng, 4)
    rt = _rt(s, apms)
    slots = s.admit(apms, embs)
    ev = s.evict(2)
    live = [int(x) for x in slots if int(x) not in ev]
    apms2, embs2 = _entries(rng, 2)
    embs2[:, 0] += 1000.0                      # distinct neighborhood
    slots2 = s.admit(apms2, embs2)
    assert set(int(x) for x in slots2) == set(ev)   # recycled, not appended
    # live entries still readable and findable, not clobbered
    for sl in live:
        _assert_payload(s.db.get([sl], count_reuse=False)[0],
                        rt[list(slots).index(sl)])
        _, idx = s.lookup(s._embs_host[sl][None], 1)
        assert int(idx[0, 0]) == sl
    # recycled slots serve the NEW entries
    dist, idx = s.lookup(embs2, 1)
    np.testing.assert_array_equal(idx[:, 0], slots2)
    _assert_payload(s.db.get(slots2, count_reuse=False), _rt(s, apms2))


# ------------------------------------------- interleaved property test

@pytest.mark.parametrize("codec", CODECS)
@settings(max_examples=8, deadline=None)
@given(seed=st_h.integers(0, 10 ** 6))
def test_interleaved_admit_evict_sync_invariants(codec, seed):
    """Random interleavings of admit/evict/note_reuse/sync preserve:
    index↔DB slot agreement for every live entry, no hits on evicted
    entries, and device-tier rows matching the host tier after sync —
    under every storage codec."""
    rng = np.random.default_rng(seed)
    s = MemoStore(APM_SHAPE, EMB_DIM, capacity=4, codec=codec,
                  budget_bytes=12 * MemoStore(APM_SHAPE, EMB_DIM,
                                              codec=codec).entry_nbytes)
    ledger = {}                                    # slot -> (apm, emb)
    serial = 0
    for _ in range(12):
        op = rng.choice(["admit", "evict", "reuse", "sync"])
        if op == "admit":
            k = int(rng.integers(1, 4))
            apms = rng.random((k, *APM_SHAPE)).astype(np.float16)
            embs = rng.normal(0, 0.01, (k, EMB_DIM)).astype(np.float32)
            embs[:, 0] += 10.0 * (serial + 1 + np.arange(k))
            serial += k
            rt = _rt(s, apms)        # ledger holds the codec round trip
            slots = s.admit(apms, embs)
            dead = [sl for sl in ledger if not s.db._live[sl]]
            for sl in dead:
                del ledger[sl]
            for j, sl in enumerate(slots):
                ledger[int(sl)] = (rt[j], embs[j])
        elif op == "evict" and s.live_count > 1:
            for sl in s.evict(int(rng.integers(1, 3))):
                ledger.pop(int(sl), None)
        elif op == "reuse" and ledger:
            sl = int(rng.choice(list(ledger)))
            s.note_reuse([sl])
        else:
            s.sync()
        # invariant: every live ledger entry is its own nearest neighbor
        for sl, (apm, emb) in ledger.items():
            dist, idx = s.lookup(emb[None], 1)
            assert int(idx[0, 0]) == sl, f"live slot {sl} lost in index"
            _assert_payload(s.db.get([sl], count_reuse=False)[0], apm)
        # invariant: dead slots are tombstoned in the index
        dead = set(range(len(s.db))) - set(ledger)
        for sl in dead:
            if sl < len(s.db) and not s.db._live[sl]:
                assert s._embs_host[sl, 0] == TOMBSTONE
    s.sync()
    # device tier mirrors the host tier for every live slot (decoded)
    dev_apms = np.asarray(s.device_db.apms)
    dev_tab = np.asarray(s.device_index.table)
    for sl, (apm, emb) in ledger.items():
        _assert_payload(dev_apms[sl], apm)
        np.testing.assert_allclose(dev_tab[sl], emb, rtol=1e-6)


# ------------------------------------------------------------- syncing

def test_sync_is_noop_when_generation_unchanged():
    """Regression for the pre-store behavior: _sync_device_tier rebuilt a
    fresh DeviceIndex (re-uploading the whole table) on EVERY resync even
    when nothing changed. The generation counter makes it a no-op."""
    rng = np.random.default_rng(7)
    s = _mk_store()
    apms, embs = _entries(rng, 6)
    s.admit(apms, embs)
    r = s.sync()
    assert r["kind"] == "full"          # first materialization
    db_obj, idx_obj = s.device_db, s.device_index
    total0 = s.stats.bytes_total
    for _ in range(3):
        r = s.sync()
        assert r["kind"] == "noop" and r["bytes"] == 0
    assert s.device_db is db_obj        # same arrays, nothing re-uploaded
    assert s.device_index is idx_obj
    assert s.stats.bytes_total == total0
    assert s.stats.n_noop_syncs == 3


@pytest.mark.parametrize("codec", CODECS)
def test_delta_sync_moves_only_changed_slots(codec):
    """Transfer-size accounting: after the initial materialization, an
    admission of k entries ships O(k) bytes (k rounded up to a power of
    two), NOT the arena — and under compression, O(k) *compressed*
    bytes (``entry_nbytes`` is codec-true)."""
    rng = np.random.default_rng(8)
    s = _mk_store(codec=codec)
    apms, embs = _entries(rng, 32)
    s.admit(apms, embs)
    s.sync()
    full_bytes = s.stats.bytes_full
    assert full_bytes > 0
    apms2, embs2 = _entries(rng, 3)
    embs2[:, 0] += 1000.0
    s.admit(apms2, embs2)
    r = s.sync()
    assert r["kind"] == "delta"
    # 3 dirty slots pad to 4 scatter rows; + slot ids for each of the
    # APM/embedding scatter and the entry-length scatter (i32 value + id)
    per_entry = s.entry_nbytes
    assert r["bytes"] <= 4 * (per_entry + 16)
    assert r["bytes"] < full_bytes / 4
    assert s.stats.bytes_delta == r["bytes"]
    # the device rows actually landed (decoded comparison under codecs)
    _assert_payload(np.asarray(s.device_db.apms)[len(s.db) - 3: len(s.db)],
                    _rt(s, apms2))


@pytest.mark.parametrize("codec", CODECS)
def test_full_resync_when_arena_outgrows_device_slack(codec):
    rng = np.random.default_rng(9)
    s = MemoStore(APM_SHAPE, EMB_DIM, capacity=4, device_slack=0.25,
                  codec=codec)
    apms, embs = _entries(rng, 8)
    s.admit(apms, embs)
    s.sync()
    cap0 = s.device_db.capacity
    apms2, embs2 = _entries(rng, cap0)     # guaranteed past the slack
    embs2[:, 0] += 1000.0
    s.admit(apms2, embs2)
    r = s.sync()
    assert r["kind"] == "full"
    assert s.device_db.capacity > cap0
    assert len(s.device_db) == len(s.db)


def test_out_of_band_db_growth_is_absorbed():
    """Backstop: code that still calls db.add/index.add directly (not via
    admit) is detected by the prefix-length check and delta-synced."""
    rng = np.random.default_rng(10)
    s = _mk_store()
    apms, embs = _entries(rng, 6)
    s.admit(apms, embs)
    s.sync()
    extra_apm = rng.random((2, *APM_SHAPE)).astype(np.float16)
    extra_emb = rng.normal(0, 0.01, (2, EMB_DIM)).astype(np.float32)
    extra_emb[:, 0] += 5000.0
    s.db.add(extra_apm)
    s.index.add(extra_emb)
    r = s.sync()
    assert r["kind"] == "delta"
    assert len(s.device_db) == 8
    assert len(s.device_index) == 8
    np.testing.assert_array_equal(np.asarray(s.device_db.apms)[6:8],
                                  extra_apm)


# ------------------------------------------------------- sim reservoir

def test_sim_reservoir_bounded_with_accurate_percentiles():
    r = SimReservoir(cap=512, seed=0)
    vals = np.random.default_rng(0).uniform(0, 1, 20_000)
    r.extend(vals.tolist())
    assert len(r) == 512                     # bounded
    assert r.seen == 20_000                  # but the stream was counted
    for q in (25, 50, 75):
        assert abs(r.percentile(q) - np.percentile(vals, q)) < 0.06
    # MemoStats default uses the reservoir
    st = MemoStats()
    st.sims.extend(range(10_000))
    assert len(st.sims) <= st.sims.cap


def test_sim_reservoir_small_streams_are_exact():
    r = SimReservoir(cap=64)
    r.extend([0.1, 0.5, 0.9])
    assert sorted(r) == [0.1, 0.5, 0.9]
    assert r.percentile(50) == 0.5


# ----------------------------------------------- engine-level lifecycle

@pytest.fixture(scope="module")
def online_engine():
    from repro.configs import get_reduced
    from repro.core.engine import MemoEngine
    from repro.memo import MemoSpec
    from repro.data import TemplateCorpus
    from repro.models import build_model

    cfg = get_reduced("bert_base").replace(n_classes=4, n_layers=2,
                                           d_model=128, d_ff=256, n_heads=4)
    m = build_model(cfg, layer_loop="unroll")
    params = m.init(jax.random.PRNGKey(0))
    corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=32, n_templates=6,
                            slot_fraction=0.2)
    eng = MemoEngine(m, params, MemoSpec.flat(threshold=0.6, embed_steps=40,
                                           mode="bucket", admit=True,
                                           budget_mb=64.0))
    batches = [{"tokens": jnp.asarray(corpus.sample(16)[0])}
               for _ in range(3)]
    eng.build(jax.random.PRNGKey(1), batches)
    return eng, corpus


class _Counting:
    def __init__(self, real, counted):
        self._real = real
        self.counts = {name: 0 for name in counted}
        for name in counted:
            setattr(self, name, self._wrap(name))

    def _wrap(self, name):
        real_fn = getattr(self._real, name)

        def fn(*a, **k):
            self.counts[name] += 1
            return real_fn(*a, **k)
        return fn

    def __getattr__(self, name):
        return getattr(self._real, name)


def _drift(cfg, seed):
    from repro.data import TemplateCorpus
    return TemplateCorpus(vocab=cfg.vocab, seq_len=32, n_templates=6,
                          slot_fraction=0.2, seed=seed)


def test_fast_path_zero_sync_with_miss_capture(online_engine, monkeypatch):
    """The acceptance invariant: miss capture (APM + embedding staging)
    must NOT reintroduce per-layer host synchronization — one trailing
    barrier, O(1) stacked transfers per batch regardless of layer count."""
    eng, corpus = online_engine
    drift = _drift(eng.cfg, 31)
    toks = jnp.asarray(drift.sample(8)[0])
    eng.infer({"tokens": toks})              # compile capture variants
    fake_jax = _Counting(jax, ["block_until_ready"])
    fake_np = _Counting(np, ["asarray", "nonzero"])
    monkeypatch.setattr(engine_mod, "jax", fake_jax)
    monkeypatch.setattr(engine_mod, "np", fake_np)
    toks2 = jnp.asarray(drift.sample(8)[0])
    _, st = eng.infer({"tokens": toks2})
    assert fake_jax.counts["block_until_ready"] == 1
    # payload + slots + embs + apms: four stacked transfers, not per-layer
    assert fake_np.counts["asarray"] <= 4
    assert fake_np.counts["nonzero"] == 0
    assert st.n_admitted > 0                 # capture actually happened


def test_admission_delta_syncs_only_changed_slots(online_engine):
    eng, corpus = online_engine
    drift = _drift(eng.cfg, 57)
    s0 = eng.store.stats
    n_delta0, bytes0 = s0.n_delta_syncs, s0.bytes_delta
    full0 = s0.n_full_syncs
    live0 = eng.store.live_count
    _, st = eng.infer({"tokens": jnp.asarray(drift.sample(8)[0])})
    assert st.n_admitted > 0
    s1 = eng.store.stats
    assert s1.n_delta_syncs > n_delta0
    assert s1.n_full_syncs == full0          # slack absorbed the batch
    shipped = s1.bytes_delta - bytes0
    # ≤ 2× the admitted rows (power-of-2 padding), NOT the arena
    assert shipped <= 2 * st.n_admitted * eng.store.entry_nbytes + 64
    assert shipped < live0 * eng.store.entry_nbytes / 2


def test_online_adaptation_recovers_vs_frozen_store(online_engine):
    """Corpus drift collapses the hit rate; admission recovers it to ≥2×
    the frozen store's post-drift rate, with logits still matching the
    select reference afterwards."""
    eng, corpus = online_engine
    drift = _drift(eng.cfg, 91)

    def run_phase(admit, n_batches, seed):
        eng.mc.admit = admit
        d = _drift(eng.cfg, 91)
        d._rng = np.random.default_rng(seed)
        st = MemoStats()
        rates = []
        for _ in range(n_batches):
            toks = jnp.asarray(d.sample(16)[0])
            h0, a0 = st.n_hits, st.n_layer_attempts
            _, st = eng.infer({"tokens": toks}, stats=st)
            rates.append((st.n_hits - h0)
                         / max(1, st.n_layer_attempts - a0))
        eng.mc.admit = True
        return rates

    frozen = run_phase(False, 5, seed=7)     # store untouched
    adaptive = run_phase(True, 5, seed=7)    # same request stream
    froz_ss = np.mean(frozen[2:])
    adap_ss = np.mean(adaptive[2:])
    assert adap_ss >= max(2 * froz_ss, 0.05), (frozen, adaptive)
    # parity vs select on drifted traffic, admission paused
    eng.mc.admit = False
    toks = jnp.asarray(drift.sample(8)[0])
    out_fast, _ = eng.infer({"tokens": toks})
    eng.mc.mode = "select"
    out_sel, _ = eng.infer({"tokens": toks})
    eng.mc.mode = "bucket"
    eng.mc.admit = True
    np.testing.assert_allclose(np.asarray(out_fast), np.asarray(out_sel),
                               rtol=2e-3, atol=2e-3)


def test_online_recalibration_refits_sim_cal(online_engine):
    """Drift makes the build-time dist→similarity map under-predict;
    recal_every refits it from captured (embedding, true-APM) pairs so
    predicted sims recover their true-similarity meaning."""
    eng, corpus = online_engine
    drift = _drift(eng.cfg, 171)
    old_every = eng.mc.recal_every
    cal0 = eng.sim_cal
    eng.mc.recal_every = 1
    try:
        poisoned = (cal0[0], cal0[1] - 10.0)   # predict sim ≈ -9: starved
        eng.sim_cal = poisoned
        for _ in range(3):
            _, st = eng.infer({"tokens": jnp.asarray(drift.sample(16)[0])})
        assert st.n_admitted > 0               # misses were captured
        a1, b1 = eng.sim_cal
        assert b1 > poisoned[1] + 1.0          # refit pulled b back up
    finally:
        eng.mc.recal_every = old_every
        eng.sim_cal = cal0


def test_host_path_capture_admits_too(online_engine):
    """Miss capture is wired through _lookup as well: the host-synchronous
    path (select mode) admits drifted misses at the batch boundary."""
    eng, corpus = online_engine
    drift = _drift(eng.cfg, 131)
    eng.mc.mode = "select"
    try:
        n0 = eng.store.stats.n_admitted
        _, st = eng.infer({"tokens": jnp.asarray(drift.sample(8)[0])})
        assert st.n_admitted > 0
        assert eng.store.stats.n_admitted > n0
    finally:
        eng.mc.mode = "bucket"

"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties.

All kernels run in interpret mode on CPU (TPU is the compile target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.memo_attention.ops import memo_attention
from repro.kernels.memo_attention.ref import memo_attention_ref
from repro.kernels.nn_search.ops import nn_search
from repro.kernels.nn_search.ref import nn_search_ref


def _qkv(key, B, S, H, Hkv, dh, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, dh), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, dh), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, dh), dtype)
    return q, k, v


def _ref_bshd(q, k, v, **kw):
    B, S, H, dh = q.shape
    Hkv = k.shape[2]
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    kt = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, dh)
    vt = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, dh)
    return attention_ref(qt, kt, vt, **kw).reshape(B, H, S, dh).transpose(
        0, 2, 1, 3)


# ------------------------------------------------------------ flash_attention

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("S,H,Hkv,dh,bq,bk", [
    (64, 4, 2, 32, 32, 16),
    (48, 2, 2, 64, 16, 16),     # S not a multiple of bigger blocks
    (33, 4, 1, 16, 16, 16),     # ragged S -> padding path
    (128, 8, 8, 64, 128, 128),  # MXU-aligned
])
def test_flash_matches_ref(dtype, tol, S, H, Hkv, dh, bq, bk):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, S, H, Hkv, dh, dtype)
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                          interpret=True)
    ref = _ref_bshd(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 8, 16])
def test_flash_masks(causal, window):
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 64, 4, 2, 32, jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=32, block_k=16, interpret=True)
    ref = _ref_bshd(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@given(S=st.integers(8, 80), H=st.sampled_from([1, 2, 4]),
       g=st.sampled_from([1, 2]), dh=st.sampled_from([16, 32]),
       seed=st.integers(0, 100))
@settings(max_examples=12, deadline=None)
def test_flash_property_rowsums(S, H, g, dh, seed):
    """Output rows are convex combinations of V rows: each output lies in
    [-max|v|, max|v|] per dim and matches the oracle."""
    Hkv = max(1, H // g)
    H = Hkv * g
    q, k, v = _qkv(jax.random.PRNGKey(seed), 1, S, H, Hkv, dh, jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                          interpret=True)
    ref = _ref_bshd(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)
    vmax = float(jnp.max(jnp.abs(v))) + 1e-5
    assert float(jnp.max(jnp.abs(out))) <= vmax


# ------------------------------------------------------------ memo_attention

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 3e-2)])
def test_memo_matches_ref(dtype, tol):
    B, S, H, Hkv, dh, N = 3, 64, 4, 2, 32, 5
    q, k, v = _qkv(jax.random.PRNGKey(2), B, S, H, Hkv, dh, dtype)
    db = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(3), (N, H, S, S)), -1
    ).astype(dtype)
    hit_idx = jnp.array([4, 0, 2])
    hit = jnp.array([1, 0, 1])
    out = memo_attention(q, k, v, db, hit_idx, hit, causal=True,
                         block_q=32, block_k=32, interpret=True)
    ref = memo_attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), db, hit_idx, hit,
                             causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_memo_all_hit_equals_apm_matmul():
    """With every sequence hitting, the kernel must reproduce APM·V with no
    dependence on Q/K at all."""
    B, S, H, dh, N = 2, 32, 2, 16, 4
    q, k, v = _qkv(jax.random.PRNGKey(4), B, S, H, H, dh, jnp.float32)
    db = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(5),
                                          (N, H, S, S)), -1)
    hit_idx = jnp.array([1, 3])
    hit = jnp.ones((B,), jnp.int32)
    out = memo_attention(q, k, v, db, hit_idx, hit, block_q=16, block_k=16,
                         interpret=True)
    out_q = memo_attention(q * 100, k * 100, v, db, hit_idx, hit,
                           block_q=16, block_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_q),
                               rtol=1e-6, atol=1e-6)
    apm = db[hit_idx]                      # (B,H,S,S)
    expect = jnp.einsum("bhqs,bshd->bqhd", apm, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_memo_no_hit_equals_flash():
    B, S, H, dh = 2, 64, 2, 32
    q, k, v = _qkv(jax.random.PRNGKey(6), B, S, H, H, dh, jnp.float32)
    db = jnp.zeros((1, H, S, S))
    out = memo_attention(q, k, v, db, jnp.zeros((B,), jnp.int32),
                         jnp.zeros((B,), jnp.int32), causal=True,
                         block_q=32, block_k=32, interpret=True)
    ref = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("H,Hkv", [(4, 1), (8, 2)])
def test_memo_matches_ref_gqa_groups(H, Hkv):
    """GQA with group > 2: the hit path's APM·V must consume the RIGHT
    shared K/V head per query head, on both implementations."""
    B, S, dh, N = 3, 64, 16, 4
    q, k, v = _qkv(jax.random.PRNGKey(10), B, S, H, Hkv, dh, jnp.float32)
    db = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(11), (N, H, S, S)), -1)
    hit_idx = jnp.array([2, 0, 3])
    hit = jnp.array([1, 0, 1])
    ref = memo_attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), db, hit_idx, hit,
                             causal=True).transpose(0, 2, 1, 3)
    for impl in ("pallas", "xla"):
        out = memo_attention(q, k, v, db, hit_idx, hit, causal=True,
                             block_q=32, block_k=32,
                             interpret=True if impl == "pallas" else None,
                             impl=impl)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5, err_msg=impl)


@pytest.mark.parametrize("causal,window", [(True, 16), (False, 8),
                                           (True, None), (False, None)])
def test_memo_masks_causal_sliding_window(causal, window):
    """Mask composition on the miss path (causal × sliding window) with a
    mixed batch: misses must match the masked oracle, hits ignore masks."""
    B, S, H, dh, N = 4, 64, 2, 16, 3
    q, k, v = _qkv(jax.random.PRNGKey(12), B, S, H, H, dh, jnp.float32)
    db = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(13), (N, H, S, S)), -1)
    hit_idx = jnp.array([1, 0, 2, 0])
    hit = jnp.array([0, 1, 1, 0])
    ref = memo_attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), db, hit_idx, hit,
                             causal=causal,
                             window=window).transpose(0, 2, 1, 3)
    for impl in ("pallas", "xla"):
        out = memo_attention(q, k, v, db, hit_idx, hit, causal=causal,
                             window=window, block_q=16, block_k=16,
                             interpret=True if impl == "pallas" else None,
                             impl=impl)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5, err_msg=impl)


def test_memo_int8_scale_boundaries():
    """int8 fused dequant at the codec's scale boundaries: rows with a
    max-magnitude element (code ±127), near-zero rows riding the 1e-4
    scale floor, and mixed hit/miss — vs the dequantize-then-f32 oracle."""
    from repro.core.codec import _quantize_rows
    from repro.kernels.memo_attention.ref import memo_attention_q8_ref
    B, S, H, dh, N = 3, 32, 2, 16, 4
    q, k, v = _qkv(jax.random.PRNGKey(14), B, S, H, H, dh, jnp.float32)
    apm = np.array(jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(15), (N, H, S, S)), -1))
    apm[0, :, 0, 0] = 1.0          # a full-magnitude element → code 127
    apm[1, :, 1, :] = 0.0          # all-zero row → scale floor path
    codes, scales = _quantize_rows(apm)
    hit_idx = jnp.array([0, 1, 3])
    hit = jnp.array([1, 1, 0])
    ref = memo_attention_q8_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), jnp.asarray(codes), jnp.asarray(scales),
        hit_idx, hit, causal=True).transpose(0, 2, 1, 3)
    for impl in ("pallas", "xla"):
        out = memo_attention(q, k, v, jnp.asarray(codes), hit_idx, hit,
                             db_scales=jnp.asarray(scales), causal=True,
                             block_q=16, block_k=16,
                             interpret=True if impl == "pallas" else None,
                             impl=impl)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5, err_msg=impl)


def test_memo_ragged_seq_padding():
    """S=96 with 64-blocks exercises the ops-level padding (the kernel
    itself asserts tile alignment); parity vs the unpadded oracle."""
    B, S, H, dh, N = 2, 96, 2, 16, 3
    q, k, v = _qkv(jax.random.PRNGKey(16), B, S, H, H, dh, jnp.float32)
    db = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(17), (N, H, S, S)), -1)
    hit_idx = jnp.array([1, 0])
    hit = jnp.array([1, 0])
    ref = memo_attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), db, hit_idx, hit,
                             causal=True).transpose(0, 2, 1, 3)
    for impl in ("pallas", "xla"):
        out = memo_attention(q, k, v, db, hit_idx, hit, causal=True,
                             block_q=64, block_k=64,
                             interpret=True if impl == "pallas" else None,
                             impl=impl)
        assert out.shape == q.shape
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5, err_msg=impl)


def test_memo_xla_impl_matches_pallas():
    """The one-matmul XLA form and the tiled kernel are one contract:
    identical outputs on a mixed batch (f16 DB and int8 DB)."""
    from repro.core.codec import _quantize_rows
    B, S, H, Hkv, dh, N = 4, 48, 4, 2, 16, 5
    q, k, v = _qkv(jax.random.PRNGKey(18), B, S, H, Hkv, dh, jnp.float32)
    apm = np.asarray(jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(19), (N, H, S, S)), -1))
    hit_idx = jnp.array([0, 4, 2, 1])
    hit = jnp.array([1, 0, 1, 0])
    a = memo_attention(q, k, v, jnp.asarray(apm), hit_idx, hit, causal=True,
                       block_q=16, block_k=16, interpret=True, impl="pallas")
    b = memo_attention(q, k, v, jnp.asarray(apm), hit_idx, hit, causal=True,
                       impl="xla")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)
    codes, scales = _quantize_rows(apm)
    aq = memo_attention(q, k, v, jnp.asarray(codes), hit_idx, hit,
                        db_scales=jnp.asarray(scales), causal=True,
                        block_q=16, block_k=16, interpret=True, impl="pallas")
    bq = memo_attention(q, k, v, jnp.asarray(codes), hit_idx, hit,
                        db_scales=jnp.asarray(scales), causal=True,
                        impl="xla")
    np.testing.assert_allclose(np.asarray(aq), np.asarray(bq),
                               rtol=2e-5, atol=2e-5)


def test_memo_varlen_lengths():
    """Variable-length batches through the ``lengths`` operand: each
    sequence's valid rows match causal flash attention run on its own
    sliced prefix (causal masking makes the slice exact)."""
    B, S, H, dh = 3, 64, 2, 16
    q, k, v = _qkv(jax.random.PRNGKey(20), B, S, H, H, dh, jnp.float32)
    lengths = jnp.array([64, 40, 17])
    db = jnp.zeros((1, H, S, S))
    zeros = jnp.zeros((B,), jnp.int32)
    for impl in ("pallas", "xla"):
        out = memo_attention(q, k, v, db, zeros, zeros, lengths=lengths,
                             causal=True, block_q=16, block_k=16,
                             interpret=True if impl == "pallas" else None,
                             impl=impl)
        for bi, L in enumerate([64, 40, 17]):
            ref = flash_attention(q[bi:bi + 1, :L], k[bi:bi + 1, :L],
                                  v[bi:bi + 1, :L], causal=True,
                                  block_q=16, block_k=16, interpret=True)
            np.testing.assert_allclose(np.asarray(out[bi, :L]),
                                       np.asarray(ref[0]),
                                       rtol=2e-5, atol=2e-5,
                                       err_msg=f"{impl} b={bi}")


# ---------------------------------------------------------------- nn_search

@pytest.mark.parametrize("B,N,dim,bq,bn", [
    (17, 1000, 128, 8, 256),
    (4, 64, 32, 4, 16),
    (128, 4096, 128, 128, 512),
])
def test_nn_search_matches_ref(B, N, dim, bq, bn):
    q = jax.random.normal(jax.random.PRNGKey(7), (B, dim))
    db = jax.random.normal(jax.random.PRNGKey(8), (N, dim))
    d, i = nn_search(q, db, block_q=bq, block_n=bn, interpret=True)
    dr, ir = nn_search_ref(q, db)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(d), np.asarray(dr),
                               rtol=1e-4, atol=1e-4)


@given(B=st.integers(1, 9), N=st.integers(2, 200),
       seed=st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_nn_search_property(B, N, seed):
    """Returned index is a true argmin: no DB entry is closer."""
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (B, 16))
    db = jax.random.normal(jax.random.PRNGKey(seed + 1), (N, 16))
    d, i = nn_search(q, db, block_q=4, block_n=32, interpret=True)
    d2_all = np.asarray(
        jnp.sum(jnp.square(q[:, None] - db[None]), -1))
    assert (np.asarray(d) <= d2_all.min(1) + 1e-4).all()
    np.testing.assert_array_equal(np.asarray(i), d2_all.argmin(1))


@pytest.mark.parametrize("B,N,dim,bq,bn", [
    (3, 250, 16, 16, 64),    # B < block_q AND N % block_n != 0 (tail mask)
    (5, 999, 32, 8, 512),    # padded tail close to a full extra block
    (2, 33, 16, 16, 32),     # single ragged DB block
])
def test_nn_search_parity_vs_exact_index(B, N, dim, bq, bn):
    """The serving-tier kernel agrees with the host-tier ExactIndex
    oracle: same argmin, and sqrt(sq_dists) == ExactIndex L2 — including
    the N-padding tail (n_total masking must keep padded DB rows out of
    the argmin) and B < block_q (query padding trimmed)."""
    from repro.core.index import ExactIndex
    rng = np.random.default_rng(B * 1000 + N)
    db = rng.normal(size=(N, dim)).astype(np.float32)
    q = rng.normal(size=(B, dim)).astype(np.float32)
    exact = ExactIndex(dim)
    exact.add(db)
    dist_ref, idx_ref = exact.search(q, 1)
    d2, idx = nn_search(jnp.asarray(q), jnp.asarray(db), block_q=bq,
                        block_n=bn, interpret=True)
    assert d2.shape == (B,) and idx.shape == (B,)
    np.testing.assert_array_equal(np.asarray(idx), idx_ref[:, 0])
    np.testing.assert_allclose(np.sqrt(np.maximum(np.asarray(d2), 0.0)),
                               dist_ref[:, 0], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,N,dim,bq,bn", [
    (3, 250, 16, 16, 64),    # B < block_q AND N % block_n != 0
    (7, 130, 32, 8, 64),     # ragged DB tail with a norms sliver
])
def test_nn_search_with_db_norms(B, N, dim, bq, bn):
    """The precomputed-norms sliver changes HBM traffic, not results:
    bitwise-equal argmin and matching distances vs the norm-free kernel,
    including the padded DB tail (padded norm entries are masked by
    n_total)."""
    rng = np.random.default_rng(B * 77 + N)
    q = jnp.asarray(rng.normal(size=(B, dim)).astype(np.float32))
    db = jnp.asarray(rng.normal(size=(N, dim)).astype(np.float32))
    norms = jnp.sum(db * db, axis=-1)
    d0, i0 = nn_search(q, db, block_q=bq, block_n=bn, interpret=True)
    d1, i1 = nn_search(q, db, db_norms=norms, block_q=bq, block_n=bn,
                       interpret=True)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1),
                               rtol=1e-5, atol=1e-5)
    dr, ir = nn_search_ref(q, db)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(ir))


def test_nn_search_exact_self_query():
    """Querying with DB rows returns identity with ~zero distance."""
    db = jax.random.normal(jax.random.PRNGKey(9), (50, 64))
    d, i = nn_search(db[:10], db, block_q=8, block_n=16, interpret=True)
    np.testing.assert_array_equal(np.asarray(i), np.arange(10))
    assert float(jnp.max(d)) < 1e-3


# ------------------------------------------------------------- rwkv6 wkv

def _wkv_inputs(key, B, S, nh, N, decay_mean=-4.0):
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, S, nh, N))
    k = jax.random.normal(ks[1], (B, S, nh, N))
    v = jax.random.normal(ks[2], (B, S, nh, N))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, S, nh, N))
                         + decay_mean))
    u = jax.random.normal(ks[4], (nh, N)) * 0.1
    return r, k, v, w, u


def _wkv_ref_model_layout(r, k, v, w, u):
    from repro.kernels.rwkv6.ref import wkv6_ref
    B, S, nh, N = r.shape
    def to_bh(t):
        return t.transpose(0, 2, 1, 3).reshape(B * nh, S, N)
    ub = jnp.broadcast_to(u[None], (B, nh, N)).reshape(B * nh, N)
    o = wkv6_ref(to_bh(r), to_bh(k), to_bh(v), to_bh(w), ub)
    return o.reshape(B, nh, S, N).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("S,chunk", [(48, 16), (41, 16), (64, 32), (8, 8)])
def test_wkv6_chunked_matches_scan(S, chunk):
    from repro.kernels.rwkv6.ops import wkv6_chunked
    r, k, v, w, u = _wkv_inputs(jax.random.PRNGKey(0), 2, S, 3, 16)
    o = wkv6_chunked(r, k, v, w, u, chunk=chunk, interpret=True)
    ref = _wkv_ref_model_layout(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


@given(seed=st.integers(0, 500), decay=st.floats(-6.0, -1.0))
@settings(max_examples=8, deadline=None)
def test_wkv6_chunked_property(seed, decay):
    """Chunk boundaries are invisible: chunked == sequential for any
    realistic data-dependent decay strength."""
    from repro.kernels.rwkv6.ops import wkv6_chunked
    r, k, v, w, u = _wkv_inputs(jax.random.PRNGKey(seed), 1, 32, 2, 8,
                                decay_mean=decay)
    o = wkv6_chunked(r, k, v, w, u, chunk=8, interpret=True)
    ref = _wkv_ref_model_layout(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                               rtol=5e-4, atol=5e-4)


def test_wkv6_in_model_matches_scan_path():
    """The backbone's rwkv mixer produces identical output with the
    chunked-kernel implementation."""
    from repro.configs import get_reduced
    from repro.models import build_model
    cfg = get_reduced("rwkv6_3b")
    key = jax.random.PRNGKey(5)
    m_scan = build_model(cfg)
    params = m_scan.init(key)
    tok = jax.random.randint(key, (2, 24), 0, cfg.vocab)
    l_scan, _, _ = m_scan.forward(params, {"tokens": tok})
    m_kern = build_model(cfg, attn_impl="pallas_interpret")
    l_kern, _, _ = m_kern.forward(params, {"tokens": tok})
    np.testing.assert_allclose(np.asarray(l_scan), np.asarray(l_kern),
                               rtol=2e-3, atol=2e-3)
